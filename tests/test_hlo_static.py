"""The HLO static analyzer: trip counts, dot FLOPs, collective parsing."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_static import analyze, parse_computations, while_trip_count


def _opt_hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    t = analyze(_opt_hlo(lambda a, b: a @ b, a, b))
    assert t.flops == 2 * 128 * 256 * 64


def test_scan_multiplies_flops():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(a):
        def body(x, _):
            return x @ a, None
        x, _ = jax.lax.scan(body, a, None, length=17)
        return x

    t = analyze(_opt_hlo(f, a))
    assert t.flops == 17 * 2 * 64 * 64 * 64


def test_nested_scan_multiplies():
    a = jnp.zeros((32, 32), jnp.float32)

    def f(a):
        def outer(x, _):
            def inner(y, _):
                return y @ a, None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        x, _ = jax.lax.scan(outer, a, None, length=3)
        return x

    t = analyze(_opt_hlo(f, a))
    assert t.flops == 15 * 2 * 32 ** 3


def test_batched_dot_flops():
    a = jnp.zeros((4, 16, 32), jnp.float32)
    b = jnp.zeros((4, 32, 8), jnp.float32)
    t = analyze(_opt_hlo(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b))
    assert t.flops == 2 * 4 * 16 * 32 * 8


def test_bytes_nonzero_and_finite():
    a = jnp.zeros((256, 256), jnp.float32)
    t = analyze(_opt_hlo(lambda a: (a @ a).sum(), a))
    assert t.bytes > 256 * 256 * 4  # at least reads the input
