"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jet as J
from repro.kernels import ops, ref
from repro.kernels.bell_tables import fdb_terms, tanh_poly_rows
from repro.kernels.jet_attention import (jet_attention_scores_pallas,
                                         jet_flash_attention_pallas,
                                         jet_rms_norm_pallas)
from repro.kernels.jet_dense import jet_dense_pallas
from repro.kernels.tanh_jet import act_jet_pallas

SHAPES = [(4, 24), (32, 130), (17, 257)]
ORDERS = [1, 3, 6]
DTYPES = [jnp.float32]  # bf16 covered once below (CPU wall-time budget)


def _tol(dtype, order):
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=5e-4, atol=10 ** -(6 - order // 3))


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_act_jet_sweep(order, shape, dtype):
    b, w = shape
    c = (jax.random.normal(jax.random.PRNGKey(order), (order + 1, b, w))
         * 0.7).astype(dtype)
    got = act_jet_pallas(c, "tanh", interpret=True)
    want = ref.act_jet_ref(c.astype(jnp.float32), "tanh").astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype, order))


@pytest.mark.parametrize("order", [1, 5])
@pytest.mark.parametrize("dims", [(8, 24, 24), (3, 260, 129)])
@pytest.mark.parametrize("activation", ["tanh", None])
def test_jet_dense_sweep(order, dims, activation):
    b, din, dout = dims
    key = jax.random.PRNGKey(1)
    c = jax.random.normal(key, (order + 1, b, din), jnp.float32) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (din, dout), jnp.float32) * 0.1
    bias = jax.random.normal(jax.random.fold_in(key, 2), (dout,), jnp.float32)
    got = jet_dense_pallas(c, w, bias, activation, interpret=True)
    want = ref.jet_dense_ref(c, w, bias, activation)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_bfloat16_path():
    c = (jax.random.normal(jax.random.PRNGKey(9), (4, 16, 64)) * 0.7
         ).astype(jnp.bfloat16)
    got = act_jet_pallas(c, "tanh", interpret=True)
    want = ref.act_jet_ref(c.astype(jnp.float32), "tanh")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


def test_block_shapes_cover_non_divisible():
    c = jax.random.normal(jax.random.PRNGKey(0), (3, 37, 291), jnp.float32)
    got = act_jet_pallas(c, "tanh", block_b=16, block_w=128, interpret=True)
    want = ref.act_jet_ref(c, "tanh")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_ref_matches_core_jet_algebra():
    """ref.py itself is validated against the independent core jet algebra."""
    c = jax.random.normal(jax.random.PRNGKey(3), (6, 5, 11), jnp.float64)
    want = J.compose(J.Jet(c), "tanh").coeffs
    got = ref.act_jet_ref(c, "tanh")
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


def test_sigmoid_kernel_path():
    c = jax.random.normal(jax.random.PRNGKey(4), (4, 9, 33), jnp.float32)
    got = ops.act_jet(c, "sigmoid")
    want = ref.act_jet_ref(c, "sigmoid")
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_sin_kernel_path():
    """The SIREN / Fourier-trunk activation runs in-kernel (cyclic
    sigma^(m)(a) = sin(a + m pi/2) stack), not via the reference fallback."""
    c = jax.random.normal(jax.random.PRNGKey(5), (5, 9, 33), jnp.float32)
    got = act_jet_pallas(c, "sin", interpret=True)
    want = ref.act_jet_ref(c, "sin")
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)
    w = jax.random.normal(jax.random.PRNGKey(6), (33, 17), jnp.float32) * 0.1
    b = jnp.zeros((17,), jnp.float32)
    got = jet_dense_pallas(c, w, b, "sin", interpret=True)
    want = ref.jet_dense_ref(c, w, b, "sin")
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused attention-score + rms_norm kernels (kernels/jet_attention.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", [1, 6])
@pytest.mark.parametrize("dims", [(5, 3, 4), (19, 2, 8), (3, 1, 1)])
def test_jet_attention_scores_sweep(order, dims):
    """Pallas (interpret) vs the straight-line ref, across batch sizes that
    do and do not divide the block, plus the degenerate single-token /
    d_head=1 shape."""
    b, t, d = dims
    key = jax.random.PRNGKey(order)
    q = jax.random.normal(key, (order + 1, b, t, d), jnp.float32) * 0.6
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (order + 1, b, t, d), jnp.float32) * 0.6
    scale = 1.0 / math.sqrt(d)
    got = jet_attention_scores_pallas(q, k, scale, block_b=8, interpret=True)
    want = ref.jet_attention_scores_ref(q, k, scale)
    np.testing.assert_allclose(got, want, rtol=5e-4,
                               atol=10 ** -(6 - order // 3))
    # probability rows sum to one at order 0, to zero at every higher order
    row_sums = jnp.sum(got, axis=-1)
    np.testing.assert_allclose(row_sums[0], 1.0, rtol=1e-5)
    if order:
        np.testing.assert_allclose(row_sums[1:], 0.0, atol=1e-5)


@pytest.mark.parametrize("order", [1, 6])
@pytest.mark.parametrize("dims", [(6, 8), (21, 16), (4, 1)])
def test_jet_rms_norm_sweep(order, dims):
    b, w = dims
    key = jax.random.PRNGKey(10 + order)
    c = jax.random.normal(key, (order + 1, b, w), jnp.float32) * 0.8
    # keep the mean square away from zero: near ms ~ eps the rsqrt jet is
    # genuinely ill-conditioned (esp. w=1) and f32 kernel-vs-ref parity
    # would measure cancellation noise, not kernel arithmetic
    c = c.at[0].set(c[0] + jnp.where(c[0] >= 0, 1.0, -1.0))
    gamma = jnp.linspace(0.5, 1.5, w, dtype=jnp.float32)
    got = jet_rms_norm_pallas(c, gamma, eps=1e-6, block_b=8, interpret=True)
    want = ref.jet_rms_norm_ref(c, gamma, 1e-6)
    np.testing.assert_allclose(got, want, rtol=5e-4,
                               atol=10 ** -(6 - order // 3))


def test_attention_ref_matches_core_jet_algebra():
    """The new refs are themselves validated against the independent core
    jet algebra (einsum Cauchy conv + softmax/rms_norm recurrences)."""
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (5, 4, 3, 6), jnp.float64) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (5, 4, 3, 6), jnp.float64) * 0.5
    scale = 1.0 / math.sqrt(6.0)
    s = J.scale(J.einsum("...qd,...kd->...qk", J.Jet(q), J.Jet(k)), scale)
    want = J.softmax(s, axis=-1).coeffs
    got = ref.jet_attention_scores_ref(q, k, scale)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)

    c = jax.random.normal(jax.random.fold_in(key, 2), (5, 4, 6), jnp.float64)
    gamma = jnp.linspace(0.5, 1.5, 6, dtype=jnp.float64)
    want = J.rms_norm(J.Jet(c), gamma, eps=1e-6).coeffs
    got = ref.jet_rms_norm_ref(c, gamma, 1e-6)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


def test_attention_scores_dispatch_folds_batch_axes():
    """ops.jet_attention_scores folds (batch, head) axes into the kernel
    grid and unfolds on the way out -- the layout SelfAttention emits."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (4, 3, 2, 3, 4), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (4, 3, 2, 3, 4), jnp.float32) * 0.5
    out = ops.jet_attention_scores(q, k, 0.5)
    assert out.shape == (4, 3, 2, 3, 3)
    for h in range(2):
        np.testing.assert_allclose(
            out[:, :, h], ops.jet_attention_scores(q[:, :, h], k[:, :, h], 0.5),
            rtol=2e-5, atol=2e-6)


def test_fused_kernels_grads_flow_through_reference_recompute():
    """The custom_vjp backward of both new ops recomputes through the ref
    path and matches autodiff of the ref directly (same contract as
    jet_dense)."""
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (3, 5, 2, 4), jnp.float64) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (3, 5, 2, 4), jnp.float64) * 0.5
    loss = lambda f: lambda a, b: jnp.sum(f(a, b) ** 2)
    g_ker = jax.grad(loss(lambda a, b: ops.jet_attention_scores(a, b, 0.5)),
                     argnums=(0, 1))(q, k)
    g_ref = jax.grad(loss(lambda a, b: ref.jet_attention_scores_ref(a, b, 0.5)),
                     argnums=(0, 1))(q, k)
    for a, b in zip(g_ker, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8)

    c = jax.random.normal(jax.random.fold_in(key, 2), (3, 5, 6), jnp.float64)
    gamma = jnp.linspace(0.5, 1.5, 6, dtype=jnp.float64)
    g_ker = jax.grad(lambda x, g: jnp.sum(ops.jet_rms_norm(x, g) ** 2),
                     argnums=(0, 1))(c, gamma)
    g_ref = jax.grad(lambda x, g: jnp.sum(ref.jet_rms_norm_ref(x, g) ** 2),
                     argnums=(0, 1))(c, gamma)
    for a, b in zip(g_ker, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8)


# ---------------------------------------------------------------------------
# single-launch flash-jet attention (kernels/jet_attention.py, PR-7)
# ---------------------------------------------------------------------------

def _flash_case(order, bsz, heads, t, dh, dm, seed=0):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kw = jax.random.split(key, 4)
    shape = (order + 1, bsz, heads, t, dh)
    q = jax.random.normal(kq, shape, jnp.float32) * 0.6
    k = jax.random.normal(kk, shape, jnp.float32) * 0.6
    v = jax.random.normal(kv, shape, jnp.float32) * 0.6
    wo = jax.random.normal(kw, (heads, dh, dm), jnp.float32) * 0.3
    return q, k, v, wo, 1.0 / math.sqrt(dh)


def _dense_keep(mask, window, t):
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    if mask == "causal":
        return j <= i
    if mask == "local":
        return (j <= i) & (i - j < window)
    return None


@pytest.mark.parametrize("order", [1, 4])
@pytest.mark.parametrize("mask,window", [("none", 0), ("causal", 0),
                                         ("local", 3)])
@pytest.mark.parametrize("dims", [(2, 2, 7, 4, 6), (3, 1, 33, 8, 5)])
def test_jet_flash_attention_sweep(order, mask, window, dims):
    """Tiled online-softmax launch vs the straight-line ref, across every
    mask variant and shapes that do NOT divide the (block_q, block_k,
    block_b) tiling -- the masked tail blocks and the running-max rescale
    both get exercised."""
    b, h, t, dh, dm = dims
    q, k, v, wo, scale = _flash_case(order, b, h, t, dh, dm, seed=order)
    got = jet_flash_attention_pallas(q, k, v, wo, scale, mask=mask,
                                     window=window, block_q=8, block_k=8,
                                     block_b=2, interpret=True)
    want = ref.jet_flash_attention_ref(q, k, v, wo, scale,
                                       mask=_dense_keep(mask, window, t))
    np.testing.assert_allclose(got, want, rtol=5e-4,
                               atol=10 ** -(6 - order // 3))


def test_flash_attention_ref_matches_core_jet_algebra():
    """ref.jet_flash_attention_ref is itself validated against the
    independent core jet algebra: scores -> J.softmax(mask=...) -> Cauchy
    value contraction -> output projection."""
    q, k, v, wo, scale = _flash_case(3, 2, 2, 6, 4, 5, seed=7)
    q, k, v, wo = (x.astype(jnp.float64) for x in (q, k, v, wo))
    keep = _dense_keep("local", 2, 6)
    s = J.scale(J.einsum("...qd,...kd->...qk", J.Jet(q), J.Jet(k)), scale)
    p = J.softmax(s, axis=-1, mask=keep)
    o = J.einsum("...qk,...kd->...qd", p, J.Jet(v))
    want = jnp.einsum("nbhqd,hdo->nbqo", o.coeffs, wo)
    got = ref.jet_flash_attention_ref(q, k, v, wo, scale, mask=keep)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


def test_flash_attention_grads_flow_through_reference_recompute():
    """custom_vjp backward of ops.jet_flash_attention recomputes through the
    ref path and matches autodiff of the ref directly."""
    q, k, v, wo, scale = _flash_case(2, 1, 2, 5, 4, 3, seed=11)
    q, k, v, wo = (x.astype(jnp.float64) for x in (q, k, v, wo))

    def loss(f):
        return lambda a, b, c, w: jnp.sum(f(a, b, c, w) ** 2)

    g_ker = jax.grad(loss(lambda a, b, c, w: ops.jet_flash_attention(
        a, b, c, w, scale, mask="causal")), argnums=(0, 1, 2, 3))(q, k, v, wo)
    keep = _dense_keep("causal", 0, 5)
    g_ref = jax.grad(loss(lambda a, b, c, w: ref.jet_flash_attention_ref(
        a, b, c, w, scale, mask=keep)), argnums=(0, 1, 2, 3))(q, k, v, wo)
    for a, b in zip(g_ker, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8)


def test_epilogue_registry_is_typed_and_complete():
    """ops.epilogues() names both the dense-kernel activations (ACTIVATION:
    evaluable by jet_dense's Taylor tables) and the dedicated fused kernels
    (FUSED_OP: rms_norm / attention_scores / flash_attention); unknown names
    are absent; the mapping is read-only."""
    reg = ops.epilogues()
    for name in ("tanh", "sigmoid", "sin"):
        assert reg[name] is ops.EpilogueKind.ACTIVATION
    for name in ("rms_norm", "attention_scores", "flash_attention"):
        assert reg[name] is ops.EpilogueKind.FUSED_OP
    for name in ("softplus", "layer_norm"):
        assert name not in reg
    with pytest.raises(TypeError):
        reg["softplus"] = ops.EpilogueKind.ACTIVATION


def test_deprecated_epilogue_shims_are_gone():
    """The PR-7 supports_epilogue / supports_activation_epilogue shims had
    a one-PR lifetime; the typed registry is the only surface now."""
    assert not hasattr(ops, "supports_epilogue")
    assert not hasattr(ops, "supports_activation_epilogue")


def test_tables_are_static_and_exact():
    rows = tanh_poly_rows(6)
    assert rows[1][:3] == (1.0, 0.0, -1.0)  # tanh' = 1 - u^2
    for k, terms in enumerate(fdb_terms(6), start=1):
        assert all(isinstance(cf, float) for cf, _, _ in terms)
        assert sum(cf for cf, _, _ in terms) == 2.0 ** (k - 1)
