"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jet as J
from repro.kernels import ops, ref
from repro.kernels.bell_tables import fdb_terms, tanh_poly_rows
from repro.kernels.jet_dense import jet_dense_pallas
from repro.kernels.tanh_jet import act_jet_pallas

SHAPES = [(4, 24), (32, 130), (17, 257)]
ORDERS = [1, 3, 6]
DTYPES = [jnp.float32]  # bf16 covered once below (CPU wall-time budget)


def _tol(dtype, order):
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=5e-4, atol=10 ** -(6 - order // 3))


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_act_jet_sweep(order, shape, dtype):
    b, w = shape
    c = (jax.random.normal(jax.random.PRNGKey(order), (order + 1, b, w))
         * 0.7).astype(dtype)
    got = act_jet_pallas(c, "tanh", interpret=True)
    want = ref.act_jet_ref(c.astype(jnp.float32), "tanh").astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype, order))


@pytest.mark.parametrize("order", [1, 5])
@pytest.mark.parametrize("dims", [(8, 24, 24), (3, 260, 129)])
@pytest.mark.parametrize("activation", ["tanh", None])
def test_jet_dense_sweep(order, dims, activation):
    b, din, dout = dims
    key = jax.random.PRNGKey(1)
    c = jax.random.normal(key, (order + 1, b, din), jnp.float32) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (din, dout), jnp.float32) * 0.1
    bias = jax.random.normal(jax.random.fold_in(key, 2), (dout,), jnp.float32)
    got = jet_dense_pallas(c, w, bias, activation, interpret=True)
    want = ref.jet_dense_ref(c, w, bias, activation)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_bfloat16_path():
    c = (jax.random.normal(jax.random.PRNGKey(9), (4, 16, 64)) * 0.7
         ).astype(jnp.bfloat16)
    got = act_jet_pallas(c, "tanh", interpret=True)
    want = ref.act_jet_ref(c.astype(jnp.float32), "tanh")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


def test_block_shapes_cover_non_divisible():
    c = jax.random.normal(jax.random.PRNGKey(0), (3, 37, 291), jnp.float32)
    got = act_jet_pallas(c, "tanh", block_b=16, block_w=128, interpret=True)
    want = ref.act_jet_ref(c, "tanh")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_ref_matches_core_jet_algebra():
    """ref.py itself is validated against the independent core jet algebra."""
    c = jax.random.normal(jax.random.PRNGKey(3), (6, 5, 11), jnp.float64)
    want = J.compose(J.Jet(c), "tanh").coeffs
    got = ref.act_jet_ref(c, "tanh")
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


def test_sigmoid_kernel_path():
    c = jax.random.normal(jax.random.PRNGKey(4), (4, 9, 33), jnp.float32)
    got = ops.act_jet(c, "sigmoid")
    want = ref.act_jet_ref(c, "sigmoid")
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_sin_kernel_path():
    """The SIREN / Fourier-trunk activation runs in-kernel (cyclic
    sigma^(m)(a) = sin(a + m pi/2) stack), not via the reference fallback."""
    c = jax.random.normal(jax.random.PRNGKey(5), (5, 9, 33), jnp.float32)
    got = act_jet_pallas(c, "sin", interpret=True)
    want = ref.act_jet_ref(c, "sin")
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)
    w = jax.random.normal(jax.random.PRNGKey(6), (33, 17), jnp.float32) * 0.1
    b = jnp.zeros((17,), jnp.float32)
    got = jet_dense_pallas(c, w, b, "sin", interpret=True)
    want = ref.jet_dense_ref(c, w, b, "sin")
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_tables_are_static_and_exact():
    rows = tanh_poly_rows(6)
    assert rows[1][:3] == (1.0, 0.0, -1.0)  # tanh' = 1 - u^2
    for k, terms in enumerate(fdb_terms(6), start=1):
        assert all(isinstance(cf, float) for cf, _, _ in terms)
        assert sum(cf for cf, _, _ in terms) == 2.0 ** (k - 1)
