"""Registry-driven jnp <-> pallas parity harness.

The safety net every fused Pallas kernel lands behind: for EVERY registered
leaf module/combinator (``repro.core.modules``) and EVERY registered
``Network`` (``repro.core.network``), ``jet_apply`` under ``impl="pallas"``
must match ``impl="jnp"`` at orders 0..4.

Coverage is asserted *from the registries*: the parametrize lists come from
``module_names()`` / ``network_names()``, so registering a new module or
network without adding a parity case here fails this file (first the
explicit coverage tests, then the KeyError in the sweep) -- a fused fast
path can never ship unchecked.

Inputs are float64 so the jnp side is a tight reference; the only pallas-
side deviation is the kernels' float32 MXU accumulation, well inside the
1e-5 gate at these shapes.  Params and coefficient stacks are built once
per case in session-scoped caches, so the full sweep stays cheap.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jet as J
from repro.core.modules import (Activation, CoordinateEmbedding, Dense,
                                FourierFeatures, MLPBlock, RMSNorm, Residual,
                                SelfAttention, Sequential, TokenPool,
                                module_names, normalize_attention_mask)
from repro.core.network import make_network, network_names

ORDERS = (0, 1, 2, 3, 4)
MAX_ORDER = max(ORDERS)
TOL = dict(rtol=1e-5, atol=1e-5)

# every attention-mask variant the API accepts, in user-facing spelling;
# the coverage test below proves this tuple spans every canonical kind, so
# a new mask variant cannot ship without joining the parity sweep
MASK_VARIANTS = (None, "causal", ("local", 2))

# one case per registered module: () -> (module, input shape).  Shapes keep
# a leading batch axis; token-axis modules carry (batch, tokens, features)
# so the pallas batch folding is exercised too.
MODULE_CASES = {
    "dense": lambda: (Dense(5, 4, "tanh"), (3, 5)),
    "activation": lambda: (Activation("sin"), (3, 5)),
    "fourier_features": lambda: (FourierFeatures(2, 4, scale=0.7), (3, 2)),
    "rms_norm": lambda: (RMSNorm(6), (3, 2, 6)),
    "self_attention": lambda: (SelfAttention(6, n_heads=2), (3, 4, 6)),
    "mlp_block": lambda: (MLPBlock(6, 12, "tanh"), (3, 6)),
    "coordinate_embedding": lambda: (CoordinateEmbedding(2, 4), (3, 2)),
    "token_pool": lambda: (TokenPool(), (3, 4, 6)),
    "sequential": lambda: (Sequential((Dense(4, 8, "sigmoid"),
                                       Dense(8, 2, None))), (3, 4)),
    "residual": lambda: (Residual(Dense(6, 6, "tanh")), (3, 6)),
}

# one case per registered network: extra make_network kwargs
NETWORK_KWARGS = {
    "dense": {},
    "mlp": {},
    "residual": {},
    "fourier": {"n_features": 4},
    "transformer": {"n_heads": 2},
}


# ---------------------------------------------------------------------------
# coverage: the case tables above must track the registries exactly
# ---------------------------------------------------------------------------

def test_every_registered_module_has_a_parity_case():
    assert set(MODULE_CASES) == set(module_names()), (
        "parity sweep out of sync with the module registry; add a case to "
        "MODULE_CASES for every registered module")


def test_every_registered_network_has_a_parity_case():
    assert set(NETWORK_KWARGS) == set(network_names()), (
        "parity sweep out of sync with the network registry; add kwargs to "
        "NETWORK_KWARGS for every registered network")


def test_every_mask_kind_has_a_parity_variant():
    from repro.core.modules import ATTENTION_MASK_KINDS
    swept = {normalize_attention_mask(m)[0] for m in MASK_VARIANTS}
    assert swept == set(ATTENTION_MASK_KINDS), (
        "masked-attention parity sweep out of sync with the mask kinds "
        "normalize_attention_mask accepts; extend MASK_VARIANTS")


# ---------------------------------------------------------------------------
# session-scoped case caches: params + a max-order coefficient stack built
# once per case; lower orders slice the same stack
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def module_cases():
    cache = {}

    def get(name):
        if name not in cache:
            mod, shape = MODULE_CASES[name]()
            seed = sum(map(ord, name))
            params = mod.init(jax.random.PRNGKey(seed), dtype=jnp.float64)
            coeffs = 0.5 * jax.random.normal(
                jax.random.PRNGKey(seed + 1),
                (MAX_ORDER + 1,) + shape, jnp.float64)
            cache[name] = (mod, params, coeffs)
        return cache[name]

    return get


@pytest.fixture(scope="session")
def network_cases():
    cache = {}

    def get(name):
        if name not in cache:
            net = make_network(name, d_in=2, d_out=1, width=8, depth=2,
                              **NETWORK_KWARGS[name])
            seed = sum(map(ord, name))
            params = net.init(jax.random.PRNGKey(seed), dtype=jnp.float64)
            coeffs = 0.5 * jax.random.normal(
                jax.random.PRNGKey(seed + 1),
                (MAX_ORDER + 1, 4, net.d_in), jnp.float64)
            cache[name] = (net, params, coeffs)
        return cache[name]

    return get


# ---------------------------------------------------------------------------
# the sweep: pallas == jnp at every order for every registry entry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("name", sorted(module_names()))
def test_module_pallas_matches_jnp(name, order, module_cases):
    mod, params, coeffs = module_cases(name)
    jet = J.Jet(coeffs[:order + 1])
    a = mod.jet_apply(params, jet, impl="jnp")
    b = mod.jet_apply(params, jet, impl="pallas")
    assert a.coeffs.shape == b.coeffs.shape
    np.testing.assert_allclose(np.asarray(a.coeffs), np.asarray(b.coeffs),
                               **TOL)


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("name", sorted(network_names()))
def test_network_pallas_matches_jnp(name, order, network_cases):
    net, params, coeffs = network_cases(name)
    jet = J.Jet(coeffs[:order + 1])
    a = net.jet_apply(params, jet, impl="jnp")
    b = net.jet_apply(params, jet, impl="pallas")
    assert a.coeffs.shape == b.coeffs.shape
    np.testing.assert_allclose(np.asarray(a.coeffs), np.asarray(b.coeffs),
                               **TOL)


# ---------------------------------------------------------------------------
# masked attention: every mask variant through the same jnp <-> pallas gate,
# at the leaf and through the full transformer trunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("mask", MASK_VARIANTS,
                         ids=[str(normalize_attention_mask(m))
                              for m in MASK_VARIANTS])
def test_masked_attention_pallas_matches_jnp(mask, order, module_cases):
    _, params, coeffs = module_cases("self_attention")
    mod = SelfAttention(6, n_heads=2, mask=mask)
    jet = J.Jet(coeffs[:order + 1])
    a = mod.jet_apply(params, jet, impl="jnp")
    b = mod.jet_apply(params, jet, impl="pallas")
    assert a.coeffs.shape == b.coeffs.shape
    np.testing.assert_allclose(np.asarray(a.coeffs), np.asarray(b.coeffs),
                               **TOL)


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("mask", MASK_VARIANTS,
                         ids=[str(normalize_attention_mask(m))
                              for m in MASK_VARIANTS])
def test_masked_transformer_pallas_matches_jnp(mask, order, network_cases):
    _, params, coeffs = network_cases("transformer")
    net = make_network("transformer", d_in=2, d_out=1, width=8, depth=2,
                       n_heads=2, mask=mask)
    jet = J.Jet(coeffs[:order + 1])
    a = net.jet_apply(params, jet, impl="jnp")
    b = net.jet_apply(params, jet, impl="pallas")
    assert a.coeffs.shape == b.coeffs.shape
    np.testing.assert_allclose(np.asarray(a.coeffs), np.asarray(b.coeffs),
                               **TOL)


# ---------------------------------------------------------------------------
# dispatch guard: parity alone cannot distinguish "fused kernel ran" from
# "silently fell back to the (identical-output) reference algebra", so the
# fused ops are counted through the module path explicitly
# ---------------------------------------------------------------------------

COUNTED_OPS = ("jet_dense", "jet_flash_attention", "jet_attention_scores",
               "jet_rms_norm")


def _count_kernel_calls(monkeypatch):
    from repro.kernels import ops as kops

    calls = {fn_name: 0 for fn_name in COUNTED_OPS}
    for fn_name in calls:
        real = getattr(kops, fn_name)

        def counted(*args, _real=real, _key=fn_name, **kwargs):
            calls[_key] += 1
            return _real(*args, **kwargs)

        monkeypatch.setattr(kops, fn_name, counted)
    return calls


@pytest.mark.parametrize("mask", MASK_VARIANTS,
                         ids=[str(normalize_attention_mask(m))
                              for m in MASK_VARIANTS])
def test_pallas_impl_actually_dispatches_fused_kernels(monkeypatch, mask):
    """impl='pallas' on the transformer trunk must INVOKE ops.jet_dense,
    ops.jet_flash_attention, and ops.jet_rms_norm (not just match their
    output) for EVERY mask variant; impl='jnp' must invoke none of them; and
    the PR-5 materializing score kernel (ops.jet_attention_scores) must
    never run -- attention goes through the tiled flash path, no silent
    fallback."""
    from repro.core.engines import NTPEngine

    calls = _count_kernel_calls(monkeypatch)
    net = make_network("transformer", d_in=2, d_out=1, width=4, depth=1,
                       n_heads=2, mask=mask)
    params = net.init(jax.random.PRNGKey(0), dtype=jnp.float64)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (3, 2), jnp.float64)

    NTPEngine("jnp").derivs(net, params, x, 2)
    assert calls == {fn_name: 0 for fn_name in COUNTED_OPS}, \
        "jnp impl must not touch the kernels"

    NTPEngine("pallas").derivs(net, params, x, 2)
    assert calls["jet_flash_attention"] == 1      # ONE tiled launch per layer
    assert calls["jet_attention_scores"] == 0     # materializing kernel: dead
    assert calls["jet_rms_norm"] == 3             # 2 pre-norms + final norm
    assert calls["jet_dense"] > 0                 # q/k/v projections + MLP
