"""Per-architecture smoke tests (reduced configs) + decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.models import (decode_state_specs, decode_step, forward_seq,
                          init_model, prefill, train_loss)
from repro.models.layers import logits as logits_fn
from repro.models.transformer import VLM_EMBED_DIM

B, S = 2, 32


def make_batch(cfg, key, seq=S):
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab)}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder.seq, cfg.d_model),
                                            jnp.float32)
    if cfg.vlm_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.vlm_image_tokens, VLM_EMBED_DIM), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch, rng):
    """One forward + one train step on CPU: output shapes + no NaNs."""
    cfg = get_arch(arch).reduced()
    params, pspecs = init_model(cfg, rng)
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(pspecs)
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: train_loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    # one SGD step moves the loss (gradients are alive end to end)
    grads = jax.grad(lambda p: train_loss(p, cfg, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_step(arch, rng):
    cfg = get_arch(arch).reduced()
    if cfg.family == "pinn":
        pytest.skip("pinn family has no decode")
    params, _ = init_model(cfg, rng)
    st = decode_state_specs(cfg, B, S, abstract=False)
    lg, st2 = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))(
        params, jnp.zeros((B, 1), jnp.int32), st)
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), arch
    assert int(st2["pos"]) == int(st["pos"]) + 1


@pytest.mark.parametrize("arch", [
    "qwen3-0.6b", "gemma3-4b", "gemma2-27b", "mixtral-8x7b",
    "whisper-large-v3", "llava-next-mistral-7b"])
def test_prefill_decode_matches_full_forward(arch, rng):
    """Ring-buffer cache + decode step == full forward on the same tokens.

    mixtral-8x7b used to xfail here: capacity-factor MoE dispatch dropped
    overflow tokens in the joint full-forward routing while a lone decode
    token never contends.  Inference dispatch is now dropless
    (apply_moe(training=False)); capacity drops are training-only."""
    cfg = get_arch(arch).reduced()
    params, _ = init_model(cfg, rng)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = make_batch(cfg, jax.random.PRNGKey(2))
    full["tokens"] = toks
    pre = dict(full)
    pre["tokens"] = toks[:, :S - 1]

    x, _, _, _ = forward_seq(params, cfg, full)
    want = logits_fn(params["embed"], x[:, -1:], cfg)[:, 0]

    _, st = prefill(params, cfg, pre, pad_to=S + (cfg.vlm_image_tokens or 0))
    got, _ = decode_step(params, cfg, toks[:, S - 1:S], st)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2, atol=2e-4)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-2.7b"])
def test_stepwise_decode_matches_forward(arch, rng):
    """Recurrent-state archs: decoding token-by-token reproduces the
    training-mode (chunked) forward at every position."""
    cfg = get_arch(arch).reduced()
    params, _ = init_model(cfg, rng)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    x, _, _, _ = forward_seq(params, cfg, {"tokens": toks})
    want = logits_fn(params["embed"], x, cfg)

    st = decode_state_specs(cfg, B, S, abstract=False)
    st["pos"] = jnp.asarray(0, jnp.int32)
    step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
    errs = []
    for t in range(S):
        lg, st = step(params, toks[:, t:t + 1], st)
        errs.append(float(np.max(np.abs(np.asarray(lg) - np.asarray(want[:, t])))))
    assert max(errs) < 5e-3, (arch, max(errs))


def test_sliding_window_blocked_vs_full(rng):
    """Blocked local attention path == full attention with a window mask."""
    from repro.models import attention as attn

    cfg = get_arch("mixtral-8x7b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, window=8, moe=None)
    mk_params, _ = init_model(cfg, rng)
    lp = jax.tree_util.tree_map(lambda a: a[0],
                                mk_params["stack"]["groups"]["layers"][0])
    x = jax.random.normal(rng, (2, 64, cfg.d_model), jnp.float32)
    got, _ = attn.blocked_attention(lp["attn"], cfg, x, window=8,
                                    q_chunk=16, kv_chunk=16)
    want, _ = attn.full_attention(lp["attn"], cfg, x, causal=True, window=8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_blocked_global_vs_full(rng):
    from repro.models import attention as attn

    cfg = get_arch("qwen3-0.6b").reduced()
    params, _ = init_model(cfg, rng)
    lp = jax.tree_util.tree_map(lambda a: a[0],
                                params["stack"]["groups"]["layers"][0])
    x = jax.random.normal(rng, (2, 64, cfg.d_model), jnp.float32)
    got, _ = attn.blocked_attention(lp["attn"], cfg, x, window=None,
                                    q_chunk=16, kv_chunk=32)
    want, _ = attn.full_attention(lp["attn"], cfg, x, causal=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_are_bounded(rng):
    """Training path: with capacity factor >= 1 and uniform routing, most
    tokens survive the capacity drops."""
    from repro.models.moe import apply_moe

    cfg = get_arch("mixtral-8x7b").reduced()
    params, _ = init_model(cfg, rng)
    lp = jax.tree_util.tree_map(lambda a: a[0],
                                params["stack"]["groups"]["layers"][0])
    x = jax.random.normal(rng, (4, 64, cfg.d_model), jnp.float32)
    y, aux = apply_moe(lp["moe"], cfg, x, training=True)
    assert y.shape == x.shape
    assert float(aux) > 0.5  # switch aux ~1 for near-uniform routing
    nonzero = float(jnp.mean(jnp.any(y != 0, axis=-1)))
    assert nonzero > 0.5


def test_moe_inference_dispatch_is_dropless(rng):
    """Inference path: every token's expert outputs survive (no capacity
    drops), the invariant behind prefill+decode == full-forward parity."""
    from repro.models.moe import apply_moe

    cfg = get_arch("mixtral-8x7b").reduced()
    params, _ = init_model(cfg, rng)
    lp = jax.tree_util.tree_map(lambda a: a[0],
                                params["stack"]["groups"]["layers"][0])
    # adversarial batch: many tokens, so joint routing would overflow under
    # the training capacity factor
    x = jax.random.normal(rng, (4, 64, cfg.d_model), jnp.float32)
    y, _ = apply_moe(lp["moe"], cfg, x, training=False)
    nonzero = float(jnp.mean(jnp.any(y != 0, axis=-1)))
    assert nonzero == 1.0
    # single-token routing (what decode sees) matches the joint routing
    y_tok = jnp.stack([apply_moe(lp["moe"], cfg, x[:, t:t + 1],
                                 training=False)[0][:, 0] for t in (0, 13)], 1)
    np.testing.assert_allclose(np.asarray(y_tok),
                               np.asarray(y[:, (0, 13)]), rtol=2e-2, atol=2e-4)
