"""The derivative-engine redesign: engines x networks agreement, spec
parsing and the deprecation shim, property tests of the jet algebra against
``jax.experimental.jet`` pushforwards (the :class:`JaxJetEngine` oracle), and
the new architectures training end-to-end."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import jet as jjet

from _compat import int_grid
from repro.core import jet as J
from repro.core import (AutodiffEngine, DenseMLP, DerivativeEngine,
                        FourierFeatureMLP, JaxJetEngine, MLP, MLPParams,
                        NTPEngine, ResidualMLP, Transformer, init_mlp,
                        make_network, network_names)
from repro.pinn import (OperatorRunConfig, get_operator, pinn_loss,
                        residual_values)
from repro.data.collocation import boundary_grid, sample_box

NETWORKS = {
    "dense": DenseMLP(2, 10, 3, 1),
    "mlp": MLP((2, 8, 12, 1)),
    "residual": ResidualMLP(2, 10, 2, 1),
    "fourier": FourierFeatureMLP(2, 10, 2, 1, n_features=6),
    # depth 1 / width 4 keeps the engine-agreement sweeps cheap (the
    # nested-autodiff oracle scales hard with both); the depth-2 width-8
    # trunk is oracle-checked through order 4 by the dedicated tests below
    "transformer": Transformer(2, 4, 1, 1, n_heads=2),
}


def _pts(n=5, d=2, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d), jnp.float64)


# ---------------------------------------------------------------------------
# engines agree on every network
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_all_engines_agree_on_derivs(name):
    net = NETWORKS[name]
    params = net.init(jax.random.PRNGKey(3), dtype=jnp.float64)
    x = _pts()
    a = NTPEngine("jnp").derivs(net, params, x, 3)
    b = AutodiffEngine().derivs(net, params, x, 3)
    c = JaxJetEngine().derivs(net, params, x, 3)
    assert a.shape == (4, x.shape[0], net.d_out)
    np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(a, c, rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_grid_and_cross_agree(name):
    net = NETWORKS[name]
    params = net.init(jax.random.PRNGKey(4), dtype=jnp.float64)
    x = _pts(4)
    np.testing.assert_allclose(NTPEngine("jnp").grid(net, params, x, 2),
                               AutodiffEngine().grid(net, params, x, 2),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(NTPEngine("jnp").cross(net, params, x, (0, 1)),
                               AutodiffEngine().cross(net, params, x, (0, 1)),
                               rtol=1e-7, atol=1e-9)


@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_pallas_impl_matches_jnp_on_networks(name):
    net = NETWORKS[name]
    params = net.init(jax.random.PRNGKey(5), dtype=jnp.float32)
    x = _pts(6).astype(jnp.float32)
    a = NTPEngine("jnp").derivs(net, params, x, 3)
    b = NTPEngine("pallas").derivs(net, params, x, 3)
    np.testing.assert_allclose(a, b, rtol=3e-3, atol=3e-4)


def test_vector_valued_network_derivs():
    net = MLP((2, 8, 3))
    params = net.init(jax.random.PRNGKey(9), dtype=jnp.float64)
    x = _pts()
    a = NTPEngine("jnp").derivs(net, params, x, 2)
    b = AutodiffEngine().derivs(net, params, x, 2)   # jacfwd tower path
    c = JaxJetEngine().derivs(net, params, x, 2)
    assert a.shape == (3, 5, 3)
    np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(a, c, rtol=1e-8, atol=1e-10)


def test_apply_matches_order_zero():
    for net in NETWORKS.values():
        params = net.init(jax.random.PRNGKey(6), dtype=jnp.float64)
        x = _pts(3)
        y = net.apply(params, x)
        np.testing.assert_allclose(y, net.apply(params, x, unroll=True),
                                   rtol=1e-12)
        np.testing.assert_allclose(
            y[None], NTPEngine("jnp").derivs(net, params, x, 0), rtol=1e-12)


# ---------------------------------------------------------------------------
# spec parsing (the engine=/impl= deprecation shim is gone: spec strings and
# engine instances are the only accepted forms)
# ---------------------------------------------------------------------------

def test_from_spec_round_trips():
    for spec, typ in (("ntp", NTPEngine), ("ntp/pallas", NTPEngine),
                      ("autodiff", AutodiffEngine), ("jet", JaxJetEngine)):
        eng = DerivativeEngine.from_spec(spec)
        assert isinstance(eng, typ)
        assert eng.spec == spec
        assert DerivativeEngine.from_spec(eng) is eng
    assert DerivativeEngine.from_spec("ntp/pallas").impl == "pallas"
    with pytest.raises(ValueError):
        DerivativeEngine.from_spec("hessian")
    with pytest.raises(ValueError):
        DerivativeEngine.from_spec("autodiff/pallas")
    with pytest.raises(ValueError):
        NTPEngine("cuda")


def test_engine_spec_parse_and_str_round_trip():
    """EngineSpec is the typed form of the spec string: parse accepts every
    user-facing spelling, str() renders the canonical short form, and the
    round trip is stable."""
    from repro.core import EngineSpec
    assert EngineSpec.parse("ntp") == EngineSpec("ntp", "jnp")
    assert EngineSpec.parse("ntp/jnp") == EngineSpec.parse("ntp")
    assert EngineSpec.parse("NTP/Pallas") == EngineSpec("ntp", "pallas")
    assert EngineSpec.parse("jax-jet") == EngineSpec("jet")
    assert str(EngineSpec.parse("ntp/jnp")) == "ntp"       # default impl short
    assert str(EngineSpec.parse("ntp/pallas")) == "ntp/pallas"
    assert str(EngineSpec.parse("autodiff")) == "autodiff"
    for spec in ("ntp", "ntp/pallas", "autodiff", "jet"):
        assert str(EngineSpec.parse(str(EngineSpec.parse(spec)))) == spec
    # parse also normalizes engine instances and passes specs through
    assert EngineSpec.parse(NTPEngine("pallas")) == EngineSpec("ntp", "pallas")
    assert EngineSpec.parse(EngineSpec("jet")) == EngineSpec("jet")
    for bad in ("hessian", "autodiff/pallas", "ntp/cuda", "jet/jnp", ""):
        with pytest.raises(ValueError, match="engine spec"):
            EngineSpec.parse(bad)


def test_engine_spec_build_matches_from_spec():
    from repro.core import EngineSpec
    eng = EngineSpec.parse("ntp/pallas").build()
    assert isinstance(eng, NTPEngine) and eng.impl == "pallas"
    assert isinstance(EngineSpec.parse("jaxjet").build(), JaxJetEngine)
    # aliases flow through from_spec too
    assert isinstance(DerivativeEngine.from_spec("jax-jet"), JaxJetEngine)


# every canonical rendering an EngineSpec can produce; the fuzz test pins
# that NO input string parses to anything outside this closed set
_CANONICAL_SPECS = {"ntp", "ntp/pallas", "autodiff", "jet"}

_FUZZ_NAMES = ("ntp", "autodiff", "jet", "jax-jet", "jaxjet", "JET",
               "", "pallas", "ntp2", "n t p", "autodif", "hessian",
               "ntp/jnp", "jet/")
_FUZZ_IMPLS = ("", "jnp", "pallas", "JNP", "Pallas", "cuda", "tpu", "x",
               "jnp/pallas")


@int_grid(("seed", 0, 100_000), max_examples=20)
def test_engine_spec_fuzz_roundtrip_or_typed_error(seed):
    """Random spec-ish strings (valid names, aliases, junk, case noise,
    stray whitespace, bogus or doubled impl suffixes) either parse to one
    of the four canonical specs -- with a stable parse/str round trip and
    a buildable engine whose own .spec re-parses to the same value -- or
    raise a ValueError carrying the offending input.  Nothing else: no
    silent fallbacks, no crashes of any other type."""
    import random

    from repro.core import EngineSpec
    rng = random.Random(seed)
    for _ in range(25):
        s = rng.choice(_FUZZ_NAMES)
        case = rng.choice((str.upper, str.lower, str.title, lambda t: t))
        s = case(s)
        if rng.random() < 0.6:
            s = f"{s}/{rng.choice(_FUZZ_IMPLS)}"
        if rng.random() < 0.3:
            s = f"  {s} "
        try:
            spec = EngineSpec.parse(s)
        except ValueError as e:
            # the typed error names the offending input verbatim
            assert "bad engine spec" in str(e) and repr(s) in str(e), (s, e)
            continue
        canonical = str(spec)
        assert canonical in _CANONICAL_SPECS, (s, canonical)
        assert EngineSpec.parse(canonical) == spec            # round trip
        assert str(EngineSpec.parse(canonical)) == canonical  # idempotent
        built = spec.build()
        assert EngineSpec.parse(built.spec) == spec           # engine agrees


def test_engine_spec_direct_constructor_validates():
    from repro.core import EngineSpec
    with pytest.raises(ValueError, match="unknown engine"):
        EngineSpec("hessian")
    with pytest.raises(ValueError, match="takes no /impl"):
        EngineSpec("autodiff", "pallas")
    with pytest.raises(ValueError, match="unknown impl"):
        EngineSpec("ntp", "cuda")
    # the default impl is filled in, making equality canonical
    assert EngineSpec("ntp") == EngineSpec("ntp", "jnp")


def test_legacy_shim_is_gone():
    """ROADMAP scheduled the PR-2 deprecation shim for removal: the
    engine=/impl= keyword pair and the bare-MLPParams reconstruction no
    longer exist anywhere on the public surface."""
    import repro.core as core
    import repro.pinn as pinn
    assert not hasattr(core, "resolve_engine")
    assert not hasattr(pinn, "resolve_net_engine")
    op = get_operator("heat")
    params = init_mlp(jax.random.PRNGKey(0), 2, 10, 2, 1, dtype=jnp.float64)
    x = sample_box(jax.random.PRNGKey(1), op.domain, 8, jnp.float64)
    with pytest.raises(TypeError):
        residual_values(params, op, x, engine="ntp", impl="jnp")
    with pytest.raises(TypeError):            # net= is now required
        residual_values(params, op, x)
    residual_values(params, op, x, net=DenseMLP(2, 10, 2, 1))  # new form ok


def test_net_must_match_operator_rank():
    """d_out/d_in mismatches raise up front instead of mis-slicing; matched
    vector networks flow through (the old d_out > 1 ValueError is gone)."""
    op = get_operator("heat")
    net = MLP((2, 8, 2))
    params = net.init(jax.random.PRNGKey(0), dtype=jnp.float64)
    x = sample_box(jax.random.PRNGKey(1), op.domain, 4, jnp.float64)
    bc = boundary_grid(op.domain, 4, jnp.float64)
    with pytest.raises(ValueError, match="d_out=2"):
        pinn_loss(params, op=op, pts=x, bc_pts=bc,
                  bc_vals=jnp.zeros(bc.shape[0]), net=net)
    with pytest.raises(ValueError, match="d_in"):
        residual_values(params, op, sample_box(jax.random.PRNGKey(1),
                                               ((0, 1),) * 3, 4, jnp.float64),
                        net=MLP((3, 8, 1)),
                        engine="ntp")


def test_network_registry():
    assert {"dense", "mlp", "residual", "fourier",
            "transformer"} <= set(network_names())
    net = make_network("fourier", d_in=3, d_out=1, width=8, depth=2,
                       n_features=4)
    assert net.d_in == 3 and net.d_out == 1
    with pytest.raises(KeyError):
        make_network("perceiver", d_in=2, d_out=1, width=8, depth=2)
    dense = make_network("dense", d_in=2, d_out=1, width=8, depth=2)
    assert isinstance(dense.init(jax.random.PRNGKey(0)), MLPParams)
    tr = make_network("transformer", d_in=2, d_out=1, width=8, depth=2,
                      n_heads=4)
    assert tr.n_heads == 4 and tr.d_out == 1
    with pytest.raises(ValueError):     # width must split across heads
        make_network("transformer", d_in=2, d_out=1, width=9, depth=1,
                     n_heads=2)


# ---------------------------------------------------------------------------
# jet-algebra property tests against jax.experimental.jet pushforwards
# ---------------------------------------------------------------------------

def _rand_jet(seed: int, order: int, shape=(3,), positive=False) -> J.Jet:
    c = 0.5 * jax.random.normal(jax.random.PRNGKey(seed),
                                (order + 1,) + shape, jnp.float64)
    if positive:
        c = c.at[0].set(jnp.abs(c[0]) + 1.0)
    return J.Jet(c)


def _jjet_raw(fn, *jets: J.Jet) -> jnp.ndarray:
    """Raw derivatives of fn(*jets) per jax.experimental.jet (the oracle)."""
    raws = [J.derivatives(j) for j in jets]
    y0, ys = jjet.jet(fn, tuple(r[0] for r in raws),
                      tuple(list(r[1:]) for r in raws))
    return jnp.stack([y0] + list(ys))


def _check(mine: J.Jet, fn, *jets: J.Jet):
    np.testing.assert_allclose(J.derivatives(mine), _jjet_raw(fn, *jets),
                               rtol=1e-8, atol=1e-9)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_exp_matches_jax_jet(order, seed):
    a = _rand_jet(seed, order)
    _check(J.exp(a), jnp.exp, a)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_log_matches_jax_jet(order, seed):
    a = _rand_jet(seed, order, positive=True)
    _check(J.log(a), jnp.log, a)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_div_matches_jax_jet(order, seed):
    a = _rand_jet(seed, order)
    b = _rand_jet(seed + 1, order, positive=True)
    _check(J.div(a, b), jnp.divide, a, b)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_powr_matches_jax_jet(order, seed):
    a = _rand_jet(seed, order, positive=True)
    _check(J.powr(a, 1.7), lambda x: jnp.power(x, 1.7), a)
    _check(J.sqrt(a), jnp.sqrt, a)
    _check(J.rsqrt(a), jax.lax.rsqrt, a)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_softmax_matches_jax_jet(order, seed):
    a = _rand_jet(seed, order, shape=(2, 4))
    _check(J.softmax(a), jax.nn.softmax, a)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_einsum_matches_jax_jet(order, seed):
    """Attention leans on jet x jet einsum: the batched score contraction
    (Cauchy convolution over the coefficient axis) and the degenerate
    jet x constant case must both match JAX's Taylor mode."""
    a = _rand_jet(seed, order, shape=(2, 3, 4))
    b = _rand_jet(seed + 1, order, shape=(2, 3, 4))
    eq = "bqd,bkd->bqk"
    _check(J.einsum(eq, a, b), lambda x, y: jnp.einsum(eq, x, y), a, b)
    # ellipsis batch form (what SelfAttention emits, with a head axis)
    ah = _rand_jet(seed + 2, order, shape=(2, 3, 2, 2))
    bh = _rand_jet(seed + 3, order, shape=(2, 3, 2, 2))
    eqh = "...qhd,...khd->...hqk"
    _check(J.einsum(eqh, ah, bh), lambda x, y: jnp.einsum(eqh, x, y), ah, bh)
    # t-constant operand degenerates to a per-coefficient contraction
    const = jnp.asarray(jax.random.normal(jax.random.PRNGKey(seed + 4),
                                          (2, 3, 4), jnp.float64))
    _check(J.einsum(eq, a, const), lambda x: jnp.einsum(eq, x, const), a)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_where_matches_jax_jet(order, seed):
    """Masked selection with a t-constant predicate (attention masking, relu):
    exact per-branch coefficients, including mask broadcast and the
    jet-vs-scalar promoted form."""
    a = _rand_jet(seed, order, shape=(3, 4))
    b = _rand_jet(seed + 1, order, shape=(3, 4))
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (3, 4))
    _check(J.where(mask, a, b), lambda x, y: jnp.where(mask, x, y), a, b)
    # mask broadcasts across leading axes
    row = jax.random.bernoulli(jax.random.PRNGKey(seed + 2), 0.5, (4,))
    _check(J.where(row, a, b), lambda x, y: jnp.where(row, x, y), a, b)
    # scalar branch promotes to a constant jet (the attention -inf fill)
    _check(J.where(mask, a, -30.0), lambda x: jnp.where(mask, x, -30.0), a)


@int_grid(("order", 1, 6), ("seed", 0, 10_000), max_examples=10)
def test_rms_norm_matches_jax_jet(order, seed):
    a = _rand_jet(seed, order, shape=(2, 4))
    gamma = jnp.linspace(0.5, 1.5, 4, dtype=jnp.float64)

    def ref(x):
        ms = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + 1e-6) * gamma

    _check(J.rms_norm(a, gamma), ref, a)


# ---------------------------------------------------------------------------
# high orders (5-6) at degenerate attention shapes: single token, d_head=1,
# n_heads=1 -- the edges a fused kernel is most likely to get wrong
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", (5, 6))
@pytest.mark.parametrize("shape", ((1, 1), (1, 3), (4, 1)))
def test_softmax_high_order_degenerate_shapes(order, shape):
    """Orders 5-6 on (rows, keys) score slabs including a single key (the
    softmax collapses to the constant 1: every higher coefficient must
    vanish exactly) and a single row."""
    a = _rand_jet(order * 7 + shape[0], order, shape=shape)
    _check(J.softmax(a), jax.nn.softmax, a)
    if shape[-1] == 1:
        p = J.softmax(a)
        np.testing.assert_allclose(p.coeffs[0], 1.0, rtol=1e-12)
        np.testing.assert_allclose(p.coeffs[1:], 0.0, atol=1e-12)


@pytest.mark.parametrize("order", (5, 6))
@pytest.mark.parametrize("width", (1, 2, 5))
def test_rms_norm_high_order_degenerate_shapes(order, width):
    """Orders 5-6 down to a single feature (rsqrt recurrence on a scalar
    mean square), primal shifted away from the ms ~ 0 singular point."""
    a = _rand_jet(order * 11 + width, order, shape=(3, width))
    a = J.Jet(a.coeffs.at[0].add(jnp.where(a.coeffs[0] >= 0, 1.0, -1.0)))
    gamma = jnp.linspace(0.7, 1.3, width, dtype=jnp.float64)

    def ref(x):
        ms = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + 1e-6) * gamma

    _check(J.rms_norm(a, gamma), ref, a)


@pytest.mark.parametrize("order", (5, 6))
@pytest.mark.parametrize("tok_d", ((1, 1), (1, 4), (3, 1)))
def test_attention_score_product_high_order_degenerate_shapes(order, tok_d):
    """The full attention-score chain (jet x jet Cauchy einsum -> scale ->
    softmax) at orders 5-6 for single-token and d_head=1 shapes, against
    jax.experimental.jet -- on BOTH the reference algebra and the fused
    kernel dispatch (ops.jet_attention_scores)."""
    from repro.kernels import ops as kops
    t, d = tok_d
    q = _rand_jet(order * 13 + t, order, shape=(2, t, d))
    k = _rand_jet(order * 13 + t + 1, order, shape=(2, t, d))
    scale = 1.0 / math.sqrt(d)

    def fn(qq, kk):
        return jax.nn.softmax(scale * jnp.einsum("bqd,bkd->bqk", qq, kk),
                              axis=-1)

    algebra = J.softmax(J.scale(J.einsum("bqd,bkd->bqk", q, k), scale))
    _check(algebra, fn, q, k)
    fused = J.Jet(kops.jet_attention_scores(q.coeffs, k.coeffs, scale))
    np.testing.assert_allclose(J.derivatives(fused), _jjet_raw(fn, q, k),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("dim,heads,tokens", [(2, 2, 3), (2, 1, 3), (4, 2, 1)])
def test_self_attention_degenerate_configs_match_jax_jet(dim, heads, tokens):
    """The SelfAttention leaf at order 5 for d_head=1, n_heads=1, and a
    single token, jnp and pallas paths both against jax.experimental.jet."""
    from jax.experimental import jet as jjet
    from repro.core.modules import SelfAttention
    attn = SelfAttention(dim, n_heads=heads)
    params = attn.init(jax.random.PRNGKey(dim * 10 + heads), jnp.float64)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(tokens),
                                (2, tokens, dim), jnp.float64)
    order = 5
    jin = _rand_jet(order + dim, order, shape=x.shape)
    raws = J.derivatives(jin)
    y0, ys = jjet.jet(lambda xx: attn.apply(params, xx),
                      (raws[0],), ([*raws[1:]],))
    want = jnp.stack([y0] + list(ys))
    for impl in ("jnp", "pallas"):
        got = J.derivatives(attn.jet_apply(params, jin, impl=impl))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# new architectures train end-to-end through the n-TangentProp engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("network,net_kwargs", [
    ("residual", {}),
    ("fourier", {"n_features": 8, "feature_scale": 0.5}),
])
def test_new_networks_train_on_registered_pde(network, net_kwargs,
                                              trained_operator):
    cfg = OperatorRunConfig(op="heat", network=network, net_kwargs=net_kwargs,
                            width=8, depth=2, adam_steps=30, adam_lr=3e-3,
                            n_domain=64, n_bc=8, log_every=10,
                            eval_pts_per_axis=8, engine="ntp")
    res = trained_operator(cfg)
    assert np.isfinite(res.l2_error)
    assert res.loss_history[-1] < res.loss_history[0]
    assert type(res.net).__name__ in ("ResidualMLP", "FourierFeatureMLP")


# ---------------------------------------------------------------------------
# the transformer trunk: oracle agreement through order 4 + e2e training
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def transformer_order4_oracles():
    """The depth-2 attention trunk's order-4 oracle stacks, computed ONCE
    for this module: the nested-autodiff tower here is by far the most
    expensive single computation in tier-1, and both the jnp and the fused
    pallas acceptance tests compare against the same reference."""
    net = Transformer(2, 8, 2, 1, n_heads=2)
    params = net.init(jax.random.PRNGKey(11), dtype=jnp.float64)
    x = _pts(4, seed=12)
    ad = AutodiffEngine().derivs(net, params, x, 4)
    jj = JaxJetEngine().derivs(net, params, x, 4)
    return net, params, x, ad, jj


def test_transformer_matches_autodiff_oracle_to_order_4(
        transformer_order4_oracles):
    """Acceptance: derivs and grid of the attention trunk match the nested
    autodiff oracle to <= 1e-4 through order 4 (they actually agree to
    float64 roundoff -- the jet algebra is exact, not approximate)."""
    net, params, x, ad, jj = transformer_order4_oracles
    a = NTPEngine("jnp").derivs(net, params, x, 4)
    assert a.shape == (5, 4, 1)
    np.testing.assert_allclose(a, ad, rtol=1e-6, atol=1e-4)
    np.testing.assert_allclose(a, jj, rtol=1e-6, atol=1e-4)
    np.testing.assert_allclose(NTPEngine("jnp").grid(net, params, x, 4),
                               AutodiffEngine().grid(net, params, x, 4),
                               rtol=1e-6, atol=1e-4)


def test_transformer_pallas_fused_matches_oracles_to_order_4(
        transformer_order4_oracles):
    """Acceptance: with the FUSED flash-attention and rms_norm kernels
    active (ntp/pallas routes SelfAttention through the single-launch
    kernels.ops.jet_flash_attention and RMSNorm through jet_rms_norm),
    the trunk still matches the nested-autodiff AND jax.experimental.jet
    oracles through order 4 within 1e-4."""
    from repro.kernels import ops as kops
    assert kops.epilogues()["flash_attention"] is kops.EpilogueKind.FUSED_OP
    assert kops.epilogues()["rms_norm"] is kops.EpilogueKind.FUSED_OP
    net, params, x, ad, jj = transformer_order4_oracles
    got = NTPEngine("pallas").derivs(net, params, x, 4)
    assert got.shape == (5, 4, 1)
    np.testing.assert_allclose(got, ad, rtol=1e-6, atol=1e-4)
    np.testing.assert_allclose(got, jj, rtol=1e-6, atol=1e-4)


@pytest.mark.parametrize("mask", (None, "causal", ("local", 2)),
                         ids=("none", "causal", "local2"))
def test_masked_transformer_flash_matches_jax_jet_to_order_4(mask):
    """Acceptance: every mask variant of the flash-jet attention trunk
    matches the independent jax.experimental.jet oracle to <= 1e-5 through
    order 4, under both impls (the oracle traces the PRIMAL apply, so the
    masked-softmax jet recurrences are checked against plain masking)."""
    net = Transformer(2, 8, 2, 1, n_heads=2, mask=mask)
    params = net.init(jax.random.PRNGKey(21), dtype=jnp.float64)
    x = _pts(4, seed=22)
    jj = JaxJetEngine().derivs(net, params, x, 4)
    for impl in ("jnp", "pallas"):
        got = NTPEngine(impl).derivs(net, params, x, 4)
        assert got.shape == (5, 4, 1)
        np.testing.assert_allclose(got, jj, rtol=1e-6, atol=1e-5)


def test_transformer_vector_output_and_cross():
    """d_out > 1 attention trunk: the component axis rides through derivs
    and the polarization cross, like every MLP-family network."""
    net = Transformer(2, 4, 1, 2, n_heads=2)
    params = net.init(jax.random.PRNGKey(13), dtype=jnp.float64)
    x = _pts(4, seed=14)
    a = NTPEngine("jnp").derivs(net, params, x, 2)
    b = AutodiffEngine().derivs(net, params, x, 2)   # jacfwd tower path
    assert a.shape == (3, 4, 2)
    np.testing.assert_allclose(a, b, rtol=1e-7, atol=1e-8)
    np.testing.assert_allclose(NTPEngine("jnp").cross(net, params, x, (0, 1)),
                               AutodiffEngine().cross(net, params, x, (0, 1)),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("engine", ("ntp", "ntp/pallas"))
def test_transformer_trains_on_registered_pde(engine, trained_operator):
    """Acceptance: make_network("transformer", ...) trains end to end on a
    registered operator under ntp AND ntp/pallas (the latter exercising the
    fused attention-score + rms_norm kernels inside the training loop)."""
    cfg = OperatorRunConfig(op="heat", network="transformer",
                            net_kwargs={"n_heads": 2}, width=8, depth=1,
                            adam_steps=30, adam_lr=1e-3, n_domain=48, n_bc=8,
                            log_every=10, eval_pts_per_axis=6, engine=engine)
    res = trained_operator(cfg)
    assert type(res.net).__name__ == "Transformer"
    assert np.isfinite(res.l2_error)
    assert res.loss_history[-1] < res.loss_history[0]
