"""Property tests for the combinatorial core (partitions / Faa di Bruno)."""

import math

from _compat import int_grid

from repro.core import (bell_number, faa_di_bruno_table, partition_count,
                        partitions, raw_bell_coefficient, total_fdb_terms)

# classical partition-function values p(0..15) (OEIS A000041)
P_KNOWN = [1, 1, 2, 3, 5, 7, 11, 15, 22, 30, 42, 56, 77, 101, 135, 176]


def test_partition_counts_match_oeis():
    for n, want in enumerate(P_KNOWN[1:], start=1):
        assert partition_count(n) == want


@int_grid(("n", 1, 14), max_examples=20)
def test_partitions_are_valid(n):
    seen = set()
    for part in partitions(n):
        assert sum(part) == n
        assert all(p >= 1 for p in part)
        assert tuple(part) == tuple(sorted(part, reverse=True))
        seen.add(part)
    assert len(seen) == partition_count(n)


@int_grid(("n", 1, 12), max_examples=20)
def test_raw_bell_coefficients_sum_to_bell_number(n):
    """sum_p n!/prod_j (j!)^{p_j} p_j! = B_n -- end-to-end generator check."""
    total = sum(raw_bell_coefficient(p, n) for p in partitions(n))
    assert total == bell_number(n)


@int_grid(("n", 1, 12), max_examples=20)
def test_fdb_table_identity_composition(n):
    """Composing with g(t) = t (u_1 = 1, rest 0) must be the identity:
    only the partition (1^n) survives and its coefficient is 1."""
    terms = [t for t in faa_di_bruno_table(n)
             if all(j == 1 for j, _ in t.powers)]
    assert len(terms) == 1
    assert terms[0].coef == 1
    assert terms[0].order == n


@int_grid(("n", 1, 10), max_examples=10)
def test_fdb_taylor_coefficients_sum(n):
    """h = f(g) with F_m = 1, u_j = 1 for all j: h_n = sum_p |p|!/prod p_j!
    = composition count of n (ordered compositions) = 2^(n-1)."""
    total = sum(t.coef for t in faa_di_bruno_table(n))
    assert total == 2 ** (n - 1)


def test_total_terms_growth_quasilinear():
    # p(n) growth: the loop work sum_{k<=n} p(k) stays tiny (paper claim)
    assert total_fdb_terms(10) == sum(P_KNOWN[1:11])
