import jax
import pytest

# PINN / core-jet precision tests need f64; smoke tests pass f32 explicitly.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def trained_operator():
    """Session-cached ``train_operator``: training smoke tests that exercise
    the same ``OperatorRunConfig`` share ONE run instead of retraining per
    test (configs are dataclasses, so their auto-repr is a stable cache
    key).  Keeps tier-1 wall clock down without losing any assertion -- each
    test still checks its own properties of the shared result."""
    cache = {}

    def run(cfg):
        from repro.pinn import train_operator
        key = repr(cfg)
        if key not in cache:
            cache[key] = train_operator(cfg)
        return cache[key]

    return run
