import jax
import pytest

# PINN / core-jet precision tests need f64; smoke tests pass f32 explicitly.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
