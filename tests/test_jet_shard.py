"""Parity/property layer for the sharded jet engine (repro.parallel.jet_shard).

Two tiers, mirroring the rest of the suite:

* **in-process** (tier-1): everything provable on the default 1-device jax --
  pad/remainder units, mesh-resolution policy, bitwise parity of a 1-device
  ``ShardedEngine`` against its inner engine (the shard_map wrapper itself
  must be a no-op on the numbers), compressor parsing/masking invariants,
  error-feedback unbiasedness, and a sharded train step checked bit-for-bit
  against the plain value_and_grad + Adam loop it claims to equal.
* **multidevice** (own CI job, ``-m multidevice``): subprocess children with
  XLA-forced host devices pin the real claims -- sharded grid/cross tables
  bit-identical (0.0 max abs diff) to the single-device launch through
  order 4 on EVERY registered operator under both ntp impls, including
  batches that don't divide the mesh; cross-process hash equality between a
  1-device and an 8-device interpreter; EF compression convergence over a
  real 8-way psum; a 4x2-mesh trainer smoke (Adam + sharded L-BFGS, with
  and without compression); and sharded serving parity + mesh-aware cache
  keys.  ``run_py`` comes from tests/test_distributed_subproc.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engines import NTPEngine
from repro.core.network import make_network
from repro.data.collocation import sample_box
from repro.parallel.compression import compressed_psum_tree, topk_mask
from repro.parallel.jet_shard import (DATA_AXIS, ShardedEngine, _compressor,
                                      build_sharded_train_step, pad_rows,
                                      resolve_mesh)
from test_distributed_subproc import run_py


def mesh1():
    return jax.make_mesh((1,), (DATA_AXIS,))


# ---------------------------------------------------------------------------
# padding / mesh resolution units
# ---------------------------------------------------------------------------

def test_pad_rows_remainder_and_identity():
    x = jnp.arange(14.0).reshape(7, 2)
    padded, n = pad_rows(x, 4)
    assert n == 7 and padded.shape == (8, 2)
    np.testing.assert_array_equal(np.asarray(padded[:7]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(padded[7:]), 0.0)
    # already divisible: the SAME array comes back, no copy, no pad
    same, n2 = pad_rows(x, 7)
    assert same is x and n2 == 7
    with pytest.raises(ValueError, match="multiple"):
        pad_rows(x, 0)


def test_resolve_mesh_policy():
    assert resolve_mesh(None, 0) is None
    assert resolve_mesh(None, None) is None
    m = resolve_mesh(None, 1)
    assert m.shape[DATA_AXIS] == 1
    # an explicit mesh wins, but must carry the data axis
    assert resolve_mesh(mesh1(), 0).shape[DATA_AXIS] == 1
    with pytest.raises(ValueError, match="no 'data' axis"):
        resolve_mesh(jax.make_mesh((1,), ("model",)))
    with pytest.raises(ValueError, match="exceeds"):
        resolve_mesh(None, jax.device_count() + 1)


def test_sharded_engine_rejects_meshes_without_data_axis():
    with pytest.raises(ValueError, match="axis"):
        ShardedEngine(NTPEngine("jnp"), jax.make_mesh((1,), ("model",)))


# ---------------------------------------------------------------------------
# 1-device shard_map wrapper is numerically a no-op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_one_device_sharded_engine_is_bitwise_identity(impl):
    """ShardedEngine over a (1,) mesh must reproduce the inner engine's
    derivs/grid/cross tables bit-for-bit -- any diff here means the wrapper
    itself (pad, shard_map, slice) perturbs the numbers."""
    eng = NTPEngine(impl)
    sh = ShardedEngine(eng, mesh1())
    assert sh.spec == eng.spec            # the mesh is an execution detail
    assert sh.n_shards == 1
    net = make_network("dense", d_in=2, d_out=1, width=8, depth=2)
    params = net.init(jax.random.PRNGKey(0), dtype=jnp.float64)
    x = sample_box(jax.random.PRNGKey(1), ((-1.0, 1.0), (0.0, 1.0)), 9,
                   jnp.float64)

    ref = eng.grid(net, params, x, 4)
    got = sh.grid(net, params, x, 4)
    assert got.shape == ref.shape == (2, 5, 9, 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    np.testing.assert_array_equal(
        np.asarray(sh.cross(net, params, x, (0, 1))),
        np.asarray(eng.cross(net, params, x, (0, 1))))

    v = jnp.full_like(x, 0.5)
    np.testing.assert_array_equal(
        np.asarray(sh.derivs(net, params, x, 3, v)),
        np.asarray(eng.derivs(net, params, x, 3, v)))


# ---------------------------------------------------------------------------
# compressor parsing and masking invariants
# ---------------------------------------------------------------------------

def test_compressor_spec_parsing():
    assert _compressor(None) is None
    assert _compressor("") is None
    assert _compressor("none") is None
    assert _compressor("NONE") is None
    assert _compressor("int8") is compressed_psum_tree
    assert callable(_compressor("topk:0.25"))
    with pytest.raises(ValueError, match="unknown grad compression"):
        _compressor("gzip")


def test_topk_mask_keeps_exactly_the_largest():
    # distinct magnitudes, shuffled, alternating signs: no ties to blur k
    mags = np.random.RandomState(0).permutation(np.arange(1.0, 101.0))
    g = jnp.asarray(mags * np.where(np.arange(100) % 2, 1.0, -1.0))
    keep = topk_mask(g, 0.1)
    assert int(keep.sum()) == 10
    assert float(jnp.min(jnp.abs(g[keep]))) > float(jnp.max(jnp.abs(g[~keep])))
    assert bool(topk_mask(g, 1.0).all())
    # at least one entry survives even for vanishing fractions
    assert int(topk_mask(g, 1e-9).sum()) == 1
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="k_frac"):
            topk_mask(g, bad)


def _reduce_loop(comp, g, err_dtype, steps):
    """Accumulate ``steps`` compressed reductions of the same per-device
    gradient block over a 1-device mesh; EF makes the running mean converge
    to the true sum."""
    mesh = mesh1()

    def body(gg, ee):
        out, e2 = comp({"g": gg}, {"g": ee}, DATA_AXIS)
        return out["g"], e2["g"]

    red = jax.jit(shard_map(body, mesh=mesh,
                            in_specs=(P(DATA_AXIS), P(DATA_AXIS)),
                            out_specs=(P(DATA_AXIS), P(DATA_AXIS)),
                            check_rep=False))
    err = jnp.zeros(g.shape, err_dtype)
    acc = jnp.zeros(g.shape[1:])
    for _ in range(steps):
        out, err = red(g, err)
        acc = acc + out[0]
    return acc / steps


@pytest.mark.parametrize("spec,tol", [("int8", 0.01), ("topk:0.2", 0.1)])
def test_error_feedback_accumulation_is_unbiased(spec, tol):
    """sum_t compressed(g) / T -> psum(g): the residual carried by error
    feedback bounds the accumulated bias by |err_T| / T."""
    comp = _compressor(spec)
    g = jax.random.normal(jax.random.PRNGKey(0), (1, 96)) * 3.0
    got = _reduce_loop(comp, g, jnp.float32, steps=100)
    rel = float(jnp.max(jnp.abs(got - g[0])) / jnp.max(jnp.abs(g)))
    assert rel < tol, rel


# ---------------------------------------------------------------------------
# sharded train step vs the plain loop it claims to equal
# ---------------------------------------------------------------------------

def _toy_loss(params, pts):
    pred = pts @ params["w"] + params["b"]
    loss = jnp.mean((pred - jnp.sin(pts[:, :1])) ** 2)
    return loss, {"residual": loss}


def test_sharded_train_step_matches_plain_adam():
    """The 1-shard sharded step equals the plain value_and_grad + Adam loop
    to float32 resolution: adam_update deliberately runs its moment/update
    math in fp32 (repro/optim/adam.py), and the two loops are DIFFERENT
    compiled programs whose fp32 rounding order may differ.  The bitwise
    claim lives at the engine level (tables above), not the optimizer."""
    from repro.optim import adam_init, adam_update

    params = {"w": jnp.full((3, 1), 0.1, jnp.float64),
              "b": jnp.zeros((1,), jnp.float64)}
    pts = jax.random.uniform(jax.random.PRNGKey(0), (16, 3), jnp.float64)

    built = build_sharded_train_step(_toy_loss, mesh1(), adam_lr=1e-2)
    assert built.n_shards == 1 and built.compression is None
    err = built.init_err(params)
    p_sh, s_sh = params, adam_init(params)
    p_ref, s_ref = params, adam_init(params)
    for _ in range(4):
        p_sh, s_sh, (loss_sh, aux), err = built.step(p_sh, s_sh, pts, err)
        (loss_ref, _), grads = jax.value_and_grad(
            _toy_loss, has_aux=True)(p_ref, pts)
        p_ref, s_ref = adam_update(grads, s_ref, p_ref, 1e-2)
        np.testing.assert_allclose(float(loss_sh), float(loss_ref),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(aux["residual"]), float(loss_sh),
                                   rtol=1e-12)
    for k in params:
        np.testing.assert_allclose(np.asarray(p_sh[k]), np.asarray(p_ref[k]),
                                   rtol=1e-6, atol=1e-9)
    # the EF state is untouched on the exact-psum path
    assert all(float(jnp.max(jnp.abs(e))) == 0.0
               for e in jax.tree_util.tree_leaves(err))


@pytest.mark.parametrize("compression", ["int8", "topk:0.5"])
def test_sharded_train_step_with_compression_descends(compression):
    from repro.optim import adam_init

    params = {"w": jnp.full((3, 1), 0.1, jnp.float64),
              "b": jnp.zeros((1,), jnp.float64)}
    pts = jax.random.uniform(jax.random.PRNGKey(0), (16, 3), jnp.float64)
    built = build_sharded_train_step(_toy_loss, mesh1(), adam_lr=1e-2,
                                     compression=compression)
    err = built.init_err(params)
    assert all(e.shape[0] == 1 for e in jax.tree_util.tree_leaves(err))
    state = adam_init(params)
    losses = []
    for _ in range(30):
        params, state, (loss, _), err = built.step(params, state, pts, err)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_pinn_loss_mesh_knob_is_bitwise_neutral():
    """pinn_loss(mesh=1-device mesh) must equal the unsharded loss exactly
    -- the knob changes execution, never the objective."""
    from repro.pinn.losses import pinn_loss
    from repro.pinn.operators import exact_values, get_operator

    op = get_operator("heat")
    net = make_network("dense", d_in=op.d_in, d_out=op.d_out, width=8,
                       depth=2)
    params = net.init(jax.random.PRNGKey(0), dtype=jnp.float64)
    pts = sample_box(jax.random.PRNGKey(1), op.domain, 12, jnp.float64)
    bc = sample_box(jax.random.PRNGKey(2), op.domain, 6, jnp.float64)
    kw = dict(op=op, pts=pts, bc_pts=bc,
              bc_vals=exact_values(op, bc, jnp.float64), net=net)
    ref, ref_aux = pinn_loss(params, **kw)
    got, got_aux = pinn_loss(params, mesh=mesh1(), **kw)
    assert float(got) == float(ref)
    assert float(got_aux["residual"]) == float(ref_aux["residual"])


# ---------------------------------------------------------------------------
# multidevice: the real parity claims, one forced-device subprocess each
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_sharded_grid_cross_bit_parity_every_operator(impl):
    """THE acceptance criterion: on an 8-device host mesh, sharded grid
    (through order 4) and cross tables are bit-identical (0.0 max abs diff)
    to the un-sharded launch for every registered operator, on a batch of
    19 rows (pad-to-24 remainder) and a 3-row batch (fewer rows than
    devices)."""
    print(run_py(f"""
        import jax, jax.numpy as jnp
        from repro.core.engines import NTPEngine
        from repro.core.network import make_network
        from repro.data.collocation import sample_box
        from repro.parallel.jet_shard import ShardedEngine, resolve_mesh
        from repro.pinn.operators import get_operator, operator_names

        eng = NTPEngine({impl!r})
        sh = ShardedEngine(eng, resolve_mesh(data_parallel=8))
        worst = 0.0
        for name in operator_names():
            op = get_operator(name)
            net = make_network("dense", d_in=op.d_in, d_out=op.d_out,
                               width=6, depth=2)
            params = net.init(jax.random.PRNGKey(0), dtype=jnp.float32)
            x = sample_box(jax.random.PRNGKey(1), op.domain, 19, jnp.float32)
            ref = eng.grid(net, params, x, 4)
            got = sh.grid(net, params, x, 4)
            assert got.shape == ref.shape == (op.d_in, 5, 19, op.d_out)
            dg = float(jnp.max(jnp.abs(got - ref)))
            crosses = op.mixed if op.mixed else \\
                (tuple(range(min(op.d_in, 2))),)
            dc = 0.0
            for axes in crosses:
                refc = eng.cross(net, params, x, axes)
                gotc = sh.cross(net, params, x, axes)
                dc = max(dc, float(jnp.max(jnp.abs(gotc - refc))))
            print(f"{{name}}: grid={{dg}} cross={{dc}} "
                  f"(crosses={{crosses}})")
            worst = max(worst, dg, dc)
        # fewer live rows than devices: 3 rows pad to 8, one row per shard
        op = get_operator("heat")
        net = make_network("dense", d_in=op.d_in, d_out=op.d_out,
                           width=6, depth=2)
        params = net.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        x3 = sample_box(jax.random.PRNGKey(2), op.domain, 3, jnp.float32)
        d3 = float(jnp.max(jnp.abs(sh.grid(net, params, x3, 4)
                                   - eng.grid(net, params, x3, 4))))
        print("tiny-batch grid diff", d3)
        worst = max(worst, d3)
        assert worst == 0.0, worst
        print("bit parity OK, impl={impl}")
    """, devices=8, timeout=600))


@pytest.mark.multidevice
def test_cross_process_bit_parity_1_vs_8_devices():
    """Stronger than in-process parity: a 1-device interpreter and an
    8-device sharded interpreter must print identical result hashes for
    the same order-4 grid -- sharding is invisible even across backends
    initialized with different device counts."""
    child = """
        import hashlib
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.engines import NTPEngine
        from repro.core.network import make_network
        from repro.data.collocation import sample_box
        from repro.parallel.jet_shard import ShardedEngine, resolve_mesh
        from repro.pinn.operators import get_operator

        op = get_operator("heat")
        net = make_network("dense", d_in=op.d_in, d_out=op.d_out,
                           width=8, depth=2)
        params = net.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        x = sample_box(jax.random.PRNGKey(1), op.domain, 19, jnp.float32)
        for impl in ("jnp", "pallas"):
            eng = NTPEngine(impl)
            if jax.device_count() > 1:
                eng = ShardedEngine(eng, resolve_mesh(
                    data_parallel=jax.device_count()))
            table = np.asarray(eng.grid(net, params, x, 4), np.float32)
            print(impl, hashlib.sha256(table.tobytes()).hexdigest())
    """
    single = run_py(child, devices=1, timeout=600)
    sharded = run_py(child, devices=8, timeout=600)
    assert single.split() == sharded.split(), (single, sharded)


@pytest.mark.multidevice
@pytest.mark.parametrize("devices", [4, 8])
def test_error_feedback_convergence_on_real_mesh(devices):
    """int8 and top-k EF reductions over a real N-way psum: the running
    mean of compressed all-reduces converges to the exact fp32 sum."""
    print(run_py(f"""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compression import (compressed_psum_tree,
                                                topk_psum_tree)

        D = {devices}
        mesh = jax.make_mesh((D,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (D, 128)) * 3.0
        true = jnp.sum(g, 0)
        cases = (("int8", compressed_psum_tree, 0.01),
                 ("topk:0.2",
                  lambda gg, ee, ax: topk_psum_tree(gg, ee, ax, k_frac=0.2),
                  0.05))
        for name, comp, tol in cases:
            red = shard_map(
                lambda gg, ee, _c=comp: tuple(
                    t["g"] for t in _c({{"g": gg}}, {{"g": ee}}, "data")),
                mesh=mesh, in_specs=(P("data"), P("data")),
                out_specs=(P("data"), P("data")), check_rep=False)
            err = jnp.zeros((D, 128), jnp.float32)
            acc = jnp.zeros((128,))
            K = 50
            for _ in range(K):
                out, err = red(g, err)
                acc = acc + out[0]
            rel = float(jnp.max(jnp.abs(acc / K - true))
                        / jnp.max(jnp.abs(true)))
            print(name, "rel", rel)
            assert rel < tol, (name, rel)
    """, devices=devices))


@pytest.mark.multidevice
def test_trainer_smoke_on_4x2_mesh():
    """train_operator end-to-end on a 4x2 ("data", "model") host mesh --
    Adam via the sharded step (plain psum AND int8 EF) plus the sharded
    L-BFGS phase; also pins the n_domain divisibility guard."""
    print(run_py("""
        import jax, numpy as np
        jax.config.update("jax_enable_x64", True)
        from repro.pinn import OperatorRunConfig, train_operator

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for compression in (None, "int8"):
            cfg = OperatorRunConfig(op="heat", width=8, depth=2, n_domain=32,
                                    n_bc=8, adam_steps=25, lbfgs_steps=3,
                                    adam_lr=2e-3, mesh=mesh, log_every=5,
                                    eval_pts_per_axis=8,
                                    grad_compression=compression)
            res = train_operator(cfg)
            assert np.isfinite(res.loss_history).all(), res.loss_history
            assert res.loss_history[-1] < res.loss_history[0], \\
                res.loss_history
            assert np.isfinite(res.l2_error)
            print(compression, res.loss_history[0], "->",
                  res.loss_history[-1], "l2", res.l2_error)
        try:
            train_operator(OperatorRunConfig(op="heat", n_domain=30,
                                             adam_steps=1, mesh=mesh))
        except ValueError as e:
            print("divisibility guard:", e)
        else:
            raise AssertionError("n_domain=30 on a 4-way data axis "
                                 "must be rejected")
    """, devices=8, timeout=600))


@pytest.mark.multidevice
def test_serving_sharded_parity_and_mesh_keyed_cache():
    """A mesh-backed DerivativeServer serves grid/cross tables bit-identical
    to JITTED direct engine calls (the serving contract since PR 6 -- the
    eager path compiles differently and sits ~1 f32 ULP away); the
    executable-cache key carries the mesh shape and bucket/mesh mismatches
    are rejected at construction."""
    print(run_py("""
        import jax, jax.numpy as jnp
        from repro.core.engines import NTPEngine
        from repro.core.network import make_network
        from repro.serving.server import DerivativeServer

        mesh = jax.make_mesh((4,), ("data",))
        net = make_network("dense", d_in=2, d_out=1, width=8, depth=2)
        params = net.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        eng = NTPEngine("jnp")
        srv = DerivativeServer(net, params, "ntp", buckets=(8, 16),
                               mesh=mesh)
        try:
            assert srv.mesh_key == (("data", 4),), srv.mesh_key
            x = jax.random.uniform(jax.random.PRNGKey(1), (5, 2),
                                   jnp.float32)
            ref_g = jax.jit(
                lambda p, xx: eng.grid(net, p, xx, 3))(params, x)
            ref_c = jax.jit(
                lambda p, xx: eng.cross(net, p, xx, (0, 1)))(params, x)
            dg = float(jnp.max(jnp.abs(srv.grid(x, 3, timeout=120)
                                       - ref_g)))
            dc = float(jnp.max(jnp.abs(srv.cross(x, (0, 1), timeout=120)
                                       - ref_c)))
            print("serving diffs", dg, dc)
            assert dg == 0.0 and dc == 0.0, (dg, dc)
        finally:
            srv.close()
        try:
            DerivativeServer(net, params, "ntp", buckets=(6,), mesh=mesh)
        except ValueError as e:
            print("bucket guard:", e)
        else:
            raise AssertionError("bucket 6 on a 4-way mesh must be "
                                 "rejected")
    """, devices=4, timeout=600))
