"""Serving subsystem: bucketing, executable cache, microbatcher semantics,
bit-identical served derivative tables, typed overload/timeout errors --
plus regression tests for this PR's bugfix sweep (launch/serve.py CLI,
ckpt/manager.py stale-tmp/leaf-mismatch, pinn/trainer.py loss history)."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import int_grid

from repro.ckpt import CheckpointManager
from repro.core.engines import DerivativeEngine
from repro.core.network import make_network
from repro.serving import (DerivativeServer, ExecutableCache, ExecutableKey,
                           RequestTimeoutError, RequestTooLargeError,
                           ServerClosedError, ServerOverloadedError,
                           pad_fraction, pad_to, pick_bucket)


@pytest.fixture(scope="module")
def net():
    return make_network("dense", d_in=2, d_out=1, width=8, depth=2)


@pytest.fixture(scope="module")
def params(net):
    return net.init(jax.random.PRNGKey(0), dtype=jnp.float64)


@pytest.fixture(scope="module")
def x5():
    return jax.random.uniform(jax.random.PRNGKey(1), (5, 2), jnp.float64)


def direct(engine, net, params, x, order):
    """The reference a served table must reproduce: a direct jitted
    engine.grid call at the request's natural (unpadded) shape."""
    return jax.jit(lambda p, xx: engine.grid(net, p, xx, order))(params, x)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_pick_bucket_smallest_admissible():
    assert pick_bucket(1, (8, 16, 32)) == 8
    assert pick_bucket(8, (8, 16, 32)) == 8      # exact fit, no pad
    assert pick_bucket(9, (8, 16, 32)) == 16
    assert pick_bucket(32, (32, 8, 16)) == 32    # unsorted config ok


def test_pick_bucket_typed_errors():
    with pytest.raises(RequestTooLargeError):
        pick_bucket(33, (8, 16, 32))
    with pytest.raises(ValueError):
        pick_bucket(0, (8, 16))


def test_pad_to_zero_rows_and_identity(x5):
    padded = pad_to(x5, 8)
    assert padded.shape == (8, 2)
    np.testing.assert_array_equal(np.asarray(padded[:5]), np.asarray(x5))
    np.testing.assert_array_equal(np.asarray(padded[5:]), 0.0)
    assert pad_to(x5, 5) is x5                    # exact fit: no copy
    forced = pad_to(x5, 5, copy=True)             # ...unless the caller (a
    assert forced is not x5                       # donating launch) needs to
    np.testing.assert_array_equal(np.asarray(forced), np.asarray(x5))
    assert pad_fraction(5, 8) == pytest.approx(3 / 8)


# ---------------------------------------------------------------------------
# bucketing properties (hypothesis when installed, dense sweep otherwise)
# ---------------------------------------------------------------------------

@int_grid(("n", 1, 512), ("seed", 0, 10_000))
def test_pick_bucket_pad_to_roundtrip_property(n, seed):
    """For every admissible n: the bucket is the SMALLEST admissible one,
    pad_to round-trips the live rows bit-for-bit, the pad is zeros, and
    pad_fraction reports exactly the wasted share of the launch."""
    from repro.serving.bucketing import DEFAULT_BUCKETS
    b = pick_bucket(n)
    assert b in DEFAULT_BUCKETS and n <= b
    assert all(n > c for c in DEFAULT_BUCKETS if c < b)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 3), jnp.float64)
    padded = pad_to(x, b)
    assert padded.shape == (b, 3) and padded.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(padded[:n]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(padded[n:]), 0.0)
    assert pad_fraction(n, b) == (b - n) / b


@int_grid(("n", 9, 512))
def test_pad_fraction_below_half_above_smallest_bucket(n):
    """The power-of-two ladder caps pad waste: any request larger than the
    smallest bucket lands in a bucket less than 2x its size."""
    from repro.serving.bucketing import DEFAULT_BUCKETS
    assert n > min(DEFAULT_BUCKETS)
    assert 0.0 <= pad_fraction(n, pick_bucket(n)) < 0.5


@int_grid(("extra", 1, 4096))
def test_pick_bucket_too_large_boundary_property(extra):
    """The largest bucket is an exact fit; one row more (and anything
    beyond) is the typed RequestTooLargeError, never a silent clamp."""
    from repro.serving.bucketing import DEFAULT_BUCKETS
    top = max(DEFAULT_BUCKETS)
    assert pick_bucket(top) == top
    with pytest.raises(RequestTooLargeError):
        pick_bucket(top + extra)


# ---------------------------------------------------------------------------
# executable cache
# ---------------------------------------------------------------------------

def _key(tag, bucket=8):
    return ExecutableKey("net", "ntp", "grid", (tag,), bucket, "float64")


def test_cache_hit_miss_counts():
    cache = ExecutableCache(capacity=4)
    fn_a, hit = cache.get_or_build(_key(1), lambda: "A")
    assert (fn_a, hit) == ("A", False)
    fn_a, hit = cache.get_or_build(_key(1), lambda: "A2")   # builder unused
    assert (fn_a, hit) == ("A", True)
    assert cache.stats() == {"hits": 1, "misses": 1, "evictions": 0,
                             "size": 1, "capacity": 4}


def test_equivalent_engine_spellings_share_one_executable(net, params, x5):
    """'ntp' and 'ntp/jnp' are the SAME engine: both servers canonicalize to
    one spec string, so across a shared cache the second spelling reuses the
    first spelling's compiled executable (a hit, not a second compile)."""
    from repro.core import EngineSpec
    assert str(EngineSpec.parse("ntp")) == str(EngineSpec.parse("ntp/jnp"))
    with DerivativeServer(net, params, "ntp", buckets=(8,),
                          flush_window_s=0.0) as a:
        a.grid(x5, 2, timeout=120.0)
        assert a.cache.stats()["misses"] == 1
        with DerivativeServer(net, params, "ntp/jnp", buckets=(8,),
                              flush_window_s=0.0) as b:
            assert b.engine_spec == a.engine_spec == "ntp"
            b.cache = a.cache          # shared cache: spellings must collide
            b.grid(x5, 2, timeout=120.0)
        stats = a.cache.stats()
        assert stats == {"hits": 1, "misses": 1, "evictions": 0,
                         "size": 1, "capacity": 32}


def test_cache_lru_eviction_at_capacity():
    cache = ExecutableCache(capacity=2)
    cache.get_or_build(_key(1), lambda: "A")
    cache.get_or_build(_key(2), lambda: "B")
    cache.get_or_build(_key(1), lambda: "A")     # A is now most-recent
    cache.get_or_build(_key(3), lambda: "C")     # evicts B, not A
    assert _key(1) in cache and _key(3) in cache
    assert _key(2) not in cache
    assert cache.stats()["evictions"] == 1
    _, hit = cache.get_or_build(_key(2), lambda: "B")   # evicted -> rebuild
    assert not hit


# ---------------------------------------------------------------------------
# served tables vs direct engine calls
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["ntp", "ntp/pallas"])
def test_served_grid_bit_identical_through_order_4(spec, net, params, x5):
    """Padding + coalescing + AOT compile must not change a single bit of
    the ntp engines' tables vs a direct engine.grid call."""
    engine = DerivativeEngine.from_spec(spec)
    with DerivativeServer(net, params, spec, buckets=(8, 16),
                          flush_window_s=0.0) as server:
        for order in (0, 3, 4):
            served = server.grid(x5, order, timeout=120.0)
            np.testing.assert_array_equal(
                np.asarray(served),
                np.asarray(direct(engine, net, params, x5, order)))


def test_served_grid_autodiff_near_exact(net, params, x5):
    """The autodiff engine's vmapped towers vectorize differently at padded
    batch sizes (one-ULP reassociation), so it is pinned to near-exact
    instead of bit-for-bit."""
    engine = DerivativeEngine.from_spec("autodiff")
    with DerivativeServer(net, params, "autodiff", buckets=(8,),
                          flush_window_s=0.0) as server:
        served = server.grid(x5, 2, timeout=120.0)
        np.testing.assert_allclose(
            np.asarray(served),
            np.asarray(direct(engine, net, params, x5, 2)),
            rtol=1e-12, atol=1e-14)


@pytest.mark.parametrize("axes", [(0, 1), (0, 0, 1)])
def test_served_cross_bit_identical(axes, net, params, x5):
    engine = DerivativeEngine.from_spec("ntp")
    ref = jax.jit(lambda p, xx: engine.cross(net, p, xx, axes))(params, x5)
    with DerivativeServer(net, params, "ntp", buckets=(8,),
                          flush_window_s=0.0) as server:
        served = server.cross(x5, axes, timeout=120.0)
    np.testing.assert_array_equal(np.asarray(served), np.asarray(ref))


def test_pad_rows_never_leak_and_requests_coalesce(net, params):
    """Two same-group requests coalesce into ONE bucketed launch; each
    caller gets exactly its own rows back."""
    engine = DerivativeEngine.from_spec("ntp")
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    xa = jax.random.uniform(k1, (3, 2), jnp.float64)
    xb = jax.random.uniform(k2, (10, 2), jnp.float64)
    server = DerivativeServer(net, params, "ntp", buckets=(4, 8, 16),
                              autostart=False)
    try:
        fa = server.submit(xa, order=2)
        fb = server.submit(xb, order=2)
        assert server._drain_once()          # one batch serves both
        ra, rb = fa.result(0), fb.result(0)
        assert ra.bucket == rb.bucket == 16  # 3 + 10 -> smallest admissible
        assert ra.batch_rows == 13
        assert ra.pad_fraction == pytest.approx(3 / 16)
        m = server.metrics()
        assert m["batches"] == 1 and m["requests"] == 2
        assert m["cache"] == {"hits": 0, "misses": 1, "evictions": 0,
                              "size": 1, "capacity": 32}
        assert ra.table.shape == (2, 3, 3, 1)
        assert rb.table.shape == (2, 3, 10, 1)
        np.testing.assert_array_equal(
            np.asarray(ra.table),
            np.asarray(direct(engine, net, params, xa, 2)))
        np.testing.assert_array_equal(
            np.asarray(rb.table),
            np.asarray(direct(engine, net, params, xb, 2)))
    finally:
        server.close()


def test_single_request_picks_smallest_bucket(net, params):
    x = jax.random.uniform(jax.random.PRNGKey(4), (3, 2), jnp.float64)
    server = DerivativeServer(net, params, "ntp", buckets=(4, 8, 16),
                              autostart=False)
    try:
        fut = server.submit(x, order=1)
        server._drain_once()
        assert fut.result(0).bucket == 4
    finally:
        server.close()


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_exact_fit_request_never_lends_client_buffer_to_donation(net, params):
    """A single request whose row count exactly fits a bucket must not reach
    a donating executable as the CLIENT's own array -- donation deletes the
    input buffer in place, and pre-fix the client's jnp array was deleted
    out from under it on accelerator backends (pad_to returns x unchanged
    on an exact fit)."""
    server = DerivativeServer(net, params, "ntp", buckets=(8,),
                              autostart=False)
    server._donate = True       # emulate an accelerator backend on CPU
    launched = {}
    orig = server.cache.get_or_build

    def spy(key, builder):
        fn, hit = orig(key, builder)

        def wrapped(p, xp):
            launched["xp"] = xp
            return fn(p, xp)
        return wrapped, hit

    server.cache.get_or_build = spy
    x = jax.random.uniform(jax.random.PRNGKey(8), (8, 2), jnp.float64)
    try:
        fut = server.submit(x, order=1)
        assert server._drain_once()
        res = fut.result(0)
    finally:
        server.close()
    assert launched["xp"] is not x          # server-owned copy, not an alias
    assert res.table.shape == (2, 2, 8, 1)
    _ = np.asarray(x)   # client's array still alive (a donated-and-deleted
    #                     array raises "Array has been deleted" here)


def test_cancelled_request_is_dropped_not_fatal(net, params, x5):
    """A client cancelling a still-queued future must not kill the worker:
    pre-fix _execute called set_result on the cancelled future, raising
    InvalidStateError through the drain loop."""
    server = DerivativeServer(net, params, "ntp", buckets=(8, 16),
                              autostart=False)
    try:
        f_cancelled = server.submit(x5, order=1)
        assert f_cancelled.cancel()          # gave up while queued
        f_live = server.submit(x5, order=1)  # same group: one batch
        assert server._drain_once()          # pre-fix: InvalidStateError
        assert f_cancelled.cancelled()
        assert f_live.result(0).table.shape == (2, 2, 5, 1)
        # a drain over nothing but cancelled requests runs no batch
        f2 = server.submit(x5, order=1)
        assert f2.cancel()
        assert not server._drain_once()
    finally:
        server.close()


def test_close_tolerates_cancelled_pending(net, params, x5):
    server = DerivativeServer(net, params, "ntp", autostart=False)
    fut = server.submit(x5, order=1)
    assert fut.cancel()
    server.close()                           # pre-fix: InvalidStateError
    assert fut.cancelled()


def test_cache_hits_across_repeated_shapes_and_eviction(net, params):
    xa = jax.random.uniform(jax.random.PRNGKey(5), (3, 2), jnp.float64)
    xb = jax.random.uniform(jax.random.PRNGKey(6), (4, 2), jnp.float64)
    server = DerivativeServer(net, params, "ntp", buckets=(4, 8),
                              cache_capacity=1, autostart=False)
    try:
        for x in (xa, xb):                   # same bucket, same order
            server.submit(x, order=1)
            server._drain_once()
        stats = server.cache.stats()
        assert stats["misses"] == 1 and stats["hits"] == 1

        server.submit(xa, order=2)           # new order -> new executable,
        server._drain_once()                 # evicting order=1 (capacity 1)
        stats = server.cache.stats()
        assert stats["misses"] == 2 and stats["evictions"] == 1
        assert stats["size"] == 1

        server.submit(xa, order=1)           # evicted -> recompile
        server._drain_once()
        assert server.cache.stats()["misses"] == 3
    finally:
        server.close()


# ---------------------------------------------------------------------------
# backpressure, timeout, lifecycle
# ---------------------------------------------------------------------------

def test_queue_overflow_raises_typed_error(net, params, x5):
    server = DerivativeServer(net, params, "ntp", max_queue=2,
                              autostart=False)
    try:
        server.submit(x5, order=1)
        server.submit(x5, order=1)
        with pytest.raises(ServerOverloadedError):
            server.submit(x5, order=1)
    finally:
        server.close()


def test_request_timeout_raises_typed_error(net, params, x5):
    server = DerivativeServer(net, params, "ntp", autostart=False)
    try:
        with pytest.raises(RequestTimeoutError):
            server.grid(x5, 1, timeout=0.05)   # no worker -> deadline hits
    finally:
        server.close()


def test_close_fails_pending_and_rejects_new(net, params, x5):
    server = DerivativeServer(net, params, "ntp", autostart=False)
    fut = server.submit(x5, order=1)
    server.close()
    with pytest.raises(ServerClosedError):
        fut.result(0)
    with pytest.raises(ServerClosedError):
        server.submit(x5, order=1)


def test_submit_validation(net, params, x5):
    server = DerivativeServer(net, params, "ntp", buckets=(8,),
                              autostart=False)
    try:
        with pytest.raises(ValueError):
            server.submit(x5)                          # neither order nor axes
        with pytest.raises(ValueError):
            server.submit(x5, order=1, axes=(0,))      # both
        with pytest.raises(ValueError):
            server.submit(x5[:, :1], order=1)          # wrong d_in
        with pytest.raises(RequestTooLargeError):
            server.submit(jnp.zeros((9, 2)), order=1)  # beyond largest bucket
    finally:
        server.close()


def test_concurrent_clients_through_worker_thread(net, params):
    """End-to-end through the real worker: concurrent clients, coalesced
    or not, every table exact."""
    engine = DerivativeEngine.from_spec("ntp")
    xs = [jax.random.uniform(k, (4, 2), jnp.float64)
          for k in jax.random.split(jax.random.PRNGKey(7), 3)]
    with DerivativeServer(net, params, "ntp", buckets=(4, 8, 16),
                          flush_window_s=0.05) as server:
        results = [None] * len(xs)

        def client(i):
            results[i] = server.grid(xs[i], 2, timeout=120.0)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(xs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        m = server.metrics()
    assert m["requests"] == 3 and 1 <= m["batches"] <= 3
    for x, table in zip(xs, results):
        np.testing.assert_array_equal(
            np.asarray(table), np.asarray(direct(engine, net, params, x, 2)))


# ---------------------------------------------------------------------------
# checkpoint-backed serving
# ---------------------------------------------------------------------------

def test_from_checkpoint_serves_restored_params(tmp_path, net, params, x5):
    CheckpointManager(str(tmp_path)).save(42, params, blocking=True)
    engine = DerivativeEngine.from_spec("ntp")
    with DerivativeServer.from_checkpoint(str(tmp_path), net,
                                          dtype=jnp.float64) as server:
        served = server.grid(x5, 2, timeout=120.0)
    np.testing.assert_array_equal(
        np.asarray(served), np.asarray(direct(engine, net, params, x5, 2)))


def test_from_checkpoint_empty_dir_is_loud(tmp_path, net):
    with pytest.raises(FileNotFoundError):
        DerivativeServer.from_checkpoint(str(tmp_path), net)


# ---------------------------------------------------------------------------
# regression: launch/serve.py CLI (flags undisableable, --greedy unused,
# --prompt-len 0 crash)
# ---------------------------------------------------------------------------

def test_serve_cli_flags_can_be_disabled():
    from repro.launch import serve as serve_cli

    args = serve_cli.parse_args([])
    assert args.reduced is True and args.greedy is True
    args = serve_cli.parse_args(["--no-reduced", "--no-greedy"])
    assert args.reduced is False and args.greedy is False


def test_serve_cli_rejects_empty_prompt():
    from repro.launch import serve as serve_cli

    with pytest.raises(SystemExit):
        serve_cli.parse_args(["--prompt-len", "0"])


def test_serve_cli_select_token_consumes_greedy():
    from repro.launch import serve as serve_cli

    logits = jnp.asarray([[0.0, 10.0, 0.0], [5.0, 0.0, 0.0]])
    tok = serve_cli.select_token(logits, greedy=True)
    assert tok.shape == (2, 1) and tok.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(tok), [[1], [0]])
    # sampling path: sharp logits make the sample deterministic, proving
    # the flag reaches the decode rule (pre-fix it was parsed, never read)
    sampled = serve_cli.select_token(1e6 * logits, greedy=False,
                                     key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(sampled), [[1], [0]])
    with pytest.raises(ValueError):
        serve_cli.select_token(logits, greedy=False)   # no key


# ---------------------------------------------------------------------------
# regression: ckpt/manager.py (stale .tmp leak, opaque restore KeyError)
# ---------------------------------------------------------------------------

def test_ckpt_stale_tmp_swept_on_init(tmp_path):
    import os

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones(2)}, blocking=True)
    stale = tmp_path / "step_0000000002.tmp"      # crashed writer's leftovers
    stale.mkdir()
    (stale / "shard_0.npz").write_bytes(b"partial")
    old = 1_000_000_000                           # long past stale_tmp_age_s
    os.utime(stale, (old, old))

    mgr2 = CheckpointManager(str(tmp_path))
    assert not stale.exists()
    assert mgr2.all_steps() == [1]
    np.testing.assert_array_equal(
        np.asarray(mgr2.restore(1, {"w": jnp.zeros(2)})["w"]), 1.0)


def test_ckpt_fresh_tmp_survives_other_managers(tmp_path):
    """A freshly-touched .tmp dir may belong to a LIVE writer in another
    manager/process (e.g. a server restoring from a directory a trainer is
    checkpointing into) -- constructing a second manager must not delete it;
    only this instance rewriting the SAME step clears its leftovers."""
    live = tmp_path / "step_0000000003.tmp"
    live.mkdir()
    (live / "shard_0.npz").write_bytes(b"in-flight")

    mgr = CheckpointManager(str(tmp_path))        # fresh mtime: not swept
    assert live.exists()

    mgr.save(3, {"w": jnp.ones(2)}, blocking=True)  # same step: tmp cleared,
    assert not live.exists()                        # write lands atomically
    np.testing.assert_array_equal(
        np.asarray(mgr.restore(3, {"w": jnp.zeros(2)})["w"]), 1.0)


def test_ckpt_restore_leaf_mismatch_is_loud(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones(2)}, blocking=True)
    # like has a leaf the checkpoint lacks -> named, not a KeyError
    with pytest.raises(ValueError, match="missing from the checkpoint.*'b'"):
        mgr.restore(1, {"a": jnp.zeros(2), "b": jnp.zeros(3)})

    mgr.save(2, {"a": jnp.ones(2), "extra": jnp.ones(1)}, blocking=True)
    with pytest.raises(ValueError, match="absent from `like`.*'extra'"):
        mgr.restore(2, {"a": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# regression: pinn/trainer.py L-BFGS loss_history double count
# ---------------------------------------------------------------------------

def test_lbfgs_loss_history_not_double_counted():
    from repro.pinn import PINNRunConfig, train

    cfg = PINNRunConfig(k=1, width=8, depth=2, n_domain=24, n_origin=8,
                        adam_steps=6, lbfgs_steps=11, log_every=3,
                        resample_every=100)
    res = train(cfg)
    # pre-fix the every-10th L-BFGS callback losses were appended AND the
    # full res.loss_history concatenated, interleaving exact duplicates
    assert len(res.loss_history) == len(set(res.loss_history))
    # lambda is still sampled during the L-BFGS phase (3 adam logs + the
    # every-10th callback)
    assert len(res.lam_history) > 3
