"""The compositional jet-module layer (repro.core.modules): leaves and
combinators against the jet/autodiff oracles, the Pallas dispatch over
batched (token) axes, the leaf registry, and the refactor guard pinning the
four pre-existing networks' parameter pytrees to their pre-module formulas
bit for bit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jet as J
from repro.core import (DenseMLP, FourierFeatureMLP, MLP, ResidualMLP,
                        Transformer)
from repro.core.modules import (Activation, CoordinateEmbedding, Dense,
                                FourierFeatures, MLPBlock, RMSNorm, Residual,
                                SelfAttention, Sequential, TokenPool,
                                make_module, module_names, register_module)
from repro.core.ntp import init_mlp, xavier_uniform
from repro.kernels import ops as kops


def _jet_of(x, order=3):
    return J.seed(x, jnp.ones_like(x), order)


def _autodiff_derivs(fn, x, v, order):
    """Directional-derivative stack of fn along v via nested jacfwd."""
    def along(xi, vi):
        g = lambda t: fn(xi + t * vi)
        outs, h = [], g
        for _ in range(order + 1):
            outs.append(h)
            h = jax.jacfwd(h)
        t0 = jnp.asarray(0.0, x.dtype)
        return jnp.stack([o(t0) for o in outs])
    return jax.vmap(along)(x, v)


def _check_module(mod, params, x, order=3, tol=1e-8):
    """jet_apply's raw derivatives match a nested-autodiff tower over apply."""
    jet = mod.jet_apply(params, _jet_of(x, order))
    got = J.derivatives(jet)
    ref = _autodiff_derivs(lambda xi: mod.apply(params, xi), x,
                           jnp.ones_like(x), order)
    np.testing.assert_allclose(got, np.moveaxis(np.asarray(ref), 0, 1),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# leaves against the autodiff oracle
# ---------------------------------------------------------------------------

def test_dense_and_activation_leaves():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 3), jnp.float64)
    mod = Dense(3, 5, "tanh")
    params = mod.init(jax.random.PRNGKey(1), dtype=jnp.float64)
    _check_module(mod, params, x)
    act = Activation("sin")
    _check_module(act, act.init(jax.random.PRNGKey(2)), x)
    # standalone Activation dispatches to the fused kernel under pallas
    xf = x.astype(jnp.float32)
    a = act.jet_apply((), _jet_of(xf, 3), impl="jnp")
    b = act.jet_apply((), _jet_of(xf, 3), impl="pallas")
    np.testing.assert_allclose(a.coeffs, b.coeffs, rtol=3e-3, atol=3e-4)


def test_rms_norm_and_mlp_block_leaves():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 6), jnp.float64)
    norm = RMSNorm(6)
    _check_module(norm, norm.init(jax.random.PRNGKey(4), dtype=jnp.float64), x)
    blk = MLPBlock(6, 12, "tanh")
    _check_module(blk, blk.init(jax.random.PRNGKey(5), dtype=jnp.float64), x)


def test_self_attention_leaf():
    """Attention on tokens (N, T, D): jet einsum/softmax against autodiff.
    Shapes stay small -- the nested-jacfwd oracle is cubic-ish in the
    flattened token block; higher orders and degenerate head/token shapes
    are covered by the (quasilinear) jax.experimental.jet checks in
    tests/test_engines.py and the registry parity sweep."""
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 3, 4), jnp.float64)
    attn = SelfAttention(4, n_heads=2)
    params = attn.init(jax.random.PRNGKey(7), dtype=jnp.float64)
    # flatten the token axes into the vmapped point for the autodiff oracle
    def fn(flat):
        return attn.apply(params, flat.reshape(3, 4)).reshape(-1)
    jet = attn.jet_apply(params, _jet_of(x, 3))
    got = J.derivatives(jet).reshape(4, 2, -1)
    ref = _autodiff_derivs(fn, x.reshape(2, -1), jnp.ones((2, 12), x.dtype), 3)
    np.testing.assert_allclose(got, np.moveaxis(np.asarray(ref), 0, 1),
                               rtol=1e-8, atol=1e-8)
    with pytest.raises(ValueError, match="divisible"):
        SelfAttention(6, n_heads=4)


def test_coordinate_embedding_and_pool():
    x = jax.random.normal(jax.random.PRNGKey(8), (5, 2), jnp.float64)
    emb = CoordinateEmbedding(2, 4)
    params = emb.init(jax.random.PRNGKey(9), dtype=jnp.float64)
    toks = emb.apply(params, x)
    assert toks.shape == (5, 2, 4)
    jet = emb.jet_apply(params, _jet_of(x, 2))
    assert jet.shape == (5, 2, 4)
    np.testing.assert_allclose(jet.primal, toks, rtol=1e-12)
    pooled = TokenPool().apply((), toks)
    np.testing.assert_allclose(pooled, toks.mean(axis=-2), rtol=1e-12)


def test_fourier_features_leaf():
    x = jax.random.normal(jax.random.PRNGKey(10), (4, 2), jnp.float64)
    ff = FourierFeatures(2, 5, scale=0.7)
    B = ff.init(jax.random.PRNGKey(11), dtype=jnp.float64)
    assert B.shape == (2, 5)
    _check_module(ff, B, x)


# ---------------------------------------------------------------------------
# combinators
# ---------------------------------------------------------------------------

def test_sequential_and_residual_compose():
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 3), jnp.float64)
    seq = Sequential((Dense(3, 8, "tanh"), Residual(Dense(8, 8, "tanh")),
                      Dense(8, 2, None)))
    params = seq.init(jax.random.PRNGKey(13), dtype=jnp.float64)
    assert len(params) == 3
    _check_module(seq, params, x)
    # residual params ARE the inner module's (no extra nesting)
    w, b = params[1]
    assert w.shape == (8, 8) and b.shape == (8,)


def test_sequential_key_split_is_stable():
    """One key per child, in order: inserting a stateless module must not
    reshuffle the parameterized siblings' initializations (the property the
    bit-identical network rewrites rely on)."""
    key = jax.random.PRNGKey(14)
    plain = Sequential((Dense(3, 4, "tanh"), Dense(4, 2, None)))
    ks = jax.random.split(key, 2)
    p = plain.init(key, dtype=jnp.float64)
    np.testing.assert_array_equal(p[0][0],
                                  xavier_uniform(ks[0], 3, 4, jnp.float64))
    np.testing.assert_array_equal(p[1][0],
                                  xavier_uniform(ks[1], 4, 2, jnp.float64))


# ---------------------------------------------------------------------------
# pallas dispatch: batched (token) axes + epilogue fallback
# ---------------------------------------------------------------------------

def test_jet_dense_folds_token_axes():
    """ops.jet_dense accepts (n+1, N, T, D) and matches the per-token
    reference -- the dispatch path every transformer Dense rides."""
    c = jax.random.normal(jax.random.PRNGKey(15), (4, 3, 2, 8), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(16), (8, 5), jnp.float32) * 0.3
    b = jnp.linspace(-0.2, 0.2, 5, dtype=jnp.float32)
    out = kops.jet_dense(c, w, b, "tanh")
    assert out.shape == (4, 3, 2, 5)
    for t in range(2):
        np.testing.assert_allclose(out[:, :, t],
                                   kops.jet_dense(c[:, :, t], w, b, "tanh"),
                                   rtol=2e-5, atol=2e-6)


def test_dense_pallas_epilogue_fallback():
    """An activation without a kernel table (softplus) still runs under
    impl='pallas': the kernel does the linear part, the jet algebra the
    activation.  Fused epilogues must be flagged correctly."""
    assert kops.epilogues().get("tanh") is kops.EpilogueKind.ACTIVATION
    assert "softplus" not in kops.epilogues()
    x = jax.random.normal(jax.random.PRNGKey(17), (4, 3), jnp.float32)
    mod = Dense(3, 6, "softplus")
    params = mod.init(jax.random.PRNGKey(18), dtype=jnp.float32)
    a = mod.jet_apply(params, _jet_of(x, 3), impl="jnp")
    b = mod.jet_apply(params, _jet_of(x, 3), impl="pallas")
    np.testing.assert_allclose(a.coeffs, b.coeffs, rtol=3e-3, atol=3e-4)
    with pytest.raises(ValueError, match="impl"):
        mod.jet_apply(params, _jet_of(x, 3), impl="cuda")


# ---------------------------------------------------------------------------
# leaf registry
# ---------------------------------------------------------------------------

def test_module_registry():
    assert {"dense", "activation", "fourier_features", "rms_norm",
            "self_attention", "mlp_block", "coordinate_embedding",
            "token_pool", "sequential", "residual"} <= set(module_names())
    mod = make_module("dense", d_in=3, d_out=4, activation="tanh")
    assert isinstance(mod, Dense)
    with pytest.raises(KeyError):
        make_module("flash_attention")
    with pytest.raises(ValueError):
        register_module("dense", Dense)  # duplicate


# ---------------------------------------------------------------------------
# refactor guard: the four pre-module networks keep their exact param
# pytrees (structure AND values) and their module graphs consume them
# ---------------------------------------------------------------------------

def test_dense_mlp_params_unchanged_by_module_refactor():
    net = DenseMLP(2, 10, 3, 1)
    key = jax.random.PRNGKey(19)
    p = net.init(key, dtype=jnp.float64)
    ref = init_mlp(key, 2, 10, 3, 1, dtype=jnp.float64)
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(a, b)


def test_mlp_params_unchanged_by_module_refactor():
    """The module-native Sequential init reproduces the pre-refactor MLP
    formula (split once per layer, xavier + zero bias) bit for bit."""
    key = jax.random.PRNGKey(20)
    widths = (2, 8, 12, 3)
    p = MLP(widths).init(key, dtype=jnp.float64)
    ks = jax.random.split(key, len(widths) - 1)
    ref = tuple(
        (xavier_uniform(k, fi, fo, jnp.float64), jnp.zeros((fo,), jnp.float64))
        for k, fi, fo in zip(ks, widths[:-1], widths[1:]))
    assert jax.tree_util.tree_structure(p) == jax.tree_util.tree_structure(ref)
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(a, b)


def test_residual_mlp_params_unchanged_by_module_refactor():
    key = jax.random.PRNGKey(21)
    p = ResidualMLP(2, 6, 2, 1).init(key, dtype=jnp.float64)
    ks = jax.random.split(key, 4)
    np.testing.assert_array_equal(p["w_in"],
                                  xavier_uniform(ks[0], 2, 6, jnp.float64))
    np.testing.assert_array_equal(p["blocks"][1][0],
                                  xavier_uniform(ks[2], 6, 6, jnp.float64))
    np.testing.assert_array_equal(p["w_out"],
                                  xavier_uniform(ks[-1], 6, 1, jnp.float64))
    assert set(p) == {"w_in", "b_in", "blocks", "w_out", "b_out"}


def test_fourier_mlp_params_unchanged_by_module_refactor():
    key = jax.random.PRNGKey(22)
    net = FourierFeatureMLP(2, 8, 2, 1, n_features=5, feature_scale=1.5)
    p = net.init(key, dtype=jnp.float64)
    kb, km = jax.random.split(key)
    np.testing.assert_array_equal(
        p["B"], 1.5 * jax.random.normal(kb, (2, 5), jnp.float64))
    ref_mlp = MLP((10, 8, 8, 1)).init(km, dtype=jnp.float64)
    for a, b in zip(jax.tree_util.tree_leaves(p["mlp"]),
                    jax.tree_util.tree_leaves(ref_mlp)):
        np.testing.assert_array_equal(a, b)
    assert set(p) == {"B", "mlp"}


def test_transformer_graph_shapes():
    """Structure sanity of the first module-native network: block count,
    token flow, head split."""
    net = Transformer(3, 8, 2, 2, n_heads=2, mlp_ratio=2)
    graph = net._graph()
    # embed + 2*(attn, mlp) + norm + pool + head
    assert len(graph.modules) == 1 + 2 * 2 + 3
    params = net.init(jax.random.PRNGKey(23), dtype=jnp.float64)
    x = jax.random.normal(jax.random.PRNGKey(24), (5, 3), jnp.float64)
    y = net.apply(params, x)
    assert y.shape == (5, 2)
    jet = net.jet_apply(params, _jet_of(x, 2))
    np.testing.assert_allclose(jet.primal, y, rtol=1e-12)
