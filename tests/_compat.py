"""Hypothesis-optional property-test decorator.

Property tests use hypothesis when it is installed.  Without it they fall
back to a deterministic, evenly-spread ``pytest.mark.parametrize`` sweep over
the same integer ranges, so ``pytest`` collects and passes (and the core
identities still get exercised across orders/seeds) in minimal environments.
"""

from __future__ import annotations

import itertools

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _spread(lo: int, hi: int, k: int) -> list[int]:
    """Up to k evenly spaced integers covering [lo, hi], endpoints included."""
    if hi - lo + 1 <= k:
        return list(range(lo, hi + 1))
    if k == 1:
        return [(lo + hi) // 2]
    step = (hi - lo) / (k - 1)
    return sorted({int(round(lo + i * step)) for i in range(k)})


def int_grid(*ranges: tuple[str, int, int], max_examples: int = 15):
    """Decorator: ``int_grid(("order", 1, 6), ("seed", 0, 1000))``.

    With hypothesis: ``@given`` over the integer ranges (randomized,
    shrinking).  Without: a parametrized sweep -- the first range is covered
    densely, later ranges are subsampled so the total case count stays near
    ``max_examples``.
    """
    if HAVE_HYPOTHESIS:
        def deco(fn):
            strats = {name: st.integers(lo, hi) for name, lo, hi in ranges}
            return settings(max_examples=max_examples, deadline=None)(
                given(**strats)(fn))
        return deco

    names = ",".join(name for name, _, _ in ranges)
    first = _spread(ranges[0][1], ranges[0][2], max_examples)
    rest_k = max(1, max_examples // max(len(first), 1))
    rest = [_spread(lo, hi, rest_k) for _, lo, hi in ranges[1:]]
    combos = [c if len(c) > 1 else c[0]
              for c in itertools.product(first, *rest)]
    return pytest.mark.parametrize(names, combos)
