"""Optimizer substrate: Adam on quadratics, L-BFGS on Rosenbrock."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam_init, adam_update, lbfgs


def test_adam_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adam_init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adam_update(g, state, params, 0.05)

    for _ in range(400):
        params, state = step(params, state)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_adam_grad_clip_and_weight_decay():
    params = {"w": jnp.asarray([10.0])}
    state = adam_init(params)
    g = {"w": jnp.asarray([1e6])}
    p2, _ = adam_update(g, state, params, 0.1, grad_clip=1.0, weight_decay=0.01)
    assert np.isfinite(float(p2["w"][0]))
    assert abs(float(p2["w"][0]) - 10.0) < 0.5  # clipped step, not 1e5


def test_lbfgs_rosenbrock():
    def rosen(p):
        x = p["x"]
        return jnp.sum(100.0 * (x[1:] - x[:-1] ** 2) ** 2 + (1 - x[:-1]) ** 2)

    vg = jax.jit(jax.value_and_grad(rosen))
    res = lbfgs(lambda p: vg(p), {"x": jnp.zeros(6, jnp.float64)}, steps=200)
    np.testing.assert_allclose(res.params["x"], jnp.ones(6), atol=1e-5)
    assert res.loss_history[-1] < 1e-10


def test_lbfgs_uses_fewer_grads_than_gd():
    """Line search: multiple f evals per step but rapid convergence."""
    def quad(p):
        return jnp.sum((p - jnp.arange(4.0)) ** 2 * jnp.asarray([1, 10, 100, 1000.]))

    vg = jax.jit(jax.value_and_grad(quad))
    res = lbfgs(lambda p: vg(p), jnp.zeros(4, jnp.float64), steps=60)
    assert res.loss_history[-1] < 1e-12
