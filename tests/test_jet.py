"""Jet algebra: property tests against nested autodiff + analytic series."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import int_grid

from repro.core import jet as J


def ref_derivs(fn, x0, v, order):
    """Directional derivatives of fn along v via nested jacfwd."""
    g = lambda t: fn(x0 + t * v)
    outs = []
    for k in range(order + 1):
        outs.append(g(0.0))
        g = jax.jacfwd(g)
    return outs


def seeded(x0, v, order):
    return J.seed(x0, v, order)


X0 = jnp.asarray([[0.3, -0.7, 1.2], [0.9, 0.1, -0.4]], jnp.float64)
V = jnp.asarray([[1.0, -0.5, 0.25], [0.2, 0.8, -1.0]], jnp.float64)


@pytest.mark.parametrize("name,jet_fn,ref_fn", [
    ("tanh", J.tanh, jnp.tanh),
    ("sigmoid", J.sigmoid, jax.nn.sigmoid),
    ("sin", J.sin, jnp.sin),
    ("softplus", J.softplus, jax.nn.softplus),
    ("exp", J.exp, jnp.exp),
    ("silu", J.silu, jax.nn.silu),
    ("gelu", J.gelu, lambda x: jax.nn.gelu(x, approximate=True)),
])
def test_scalar_functions_to_order_6(name, jet_fn, ref_fn):
    order = 6
    out = J.derivatives(jet_fn(seeded(X0, V, order)))
    refs = ref_derivs(ref_fn, X0, V, order)
    for k in range(order + 1):
        np.testing.assert_allclose(out[k], refs[k], rtol=1e-8, atol=1e-8,
                                   err_msg=f"{name} order {k}")


@pytest.mark.parametrize("name,jet_fn,ref_fn", [
    ("log", J.log, jnp.log),
    ("sqrt", J.sqrt, jnp.sqrt),
    ("rsqrt", J.rsqrt, jax.lax.rsqrt),
    ("recip", lambda a: J.div(1.0, a), lambda x: 1.0 / x),
])
def test_positive_domain_functions(name, jet_fn, ref_fn):
    x0 = jnp.abs(X0) + 1.5
    order = 5
    out = J.derivatives(jet_fn(seeded(x0, V, order)))
    refs = ref_derivs(ref_fn, x0, V, order)
    for k in range(order + 1):
        np.testing.assert_allclose(out[k], refs[k], rtol=1e-7, atol=1e-9,
                                   err_msg=f"{name} order {k}")


@int_grid(("order", 1, 7), max_examples=7)
def test_mul_is_cauchy_convolution(order):
    a = seeded(X0, V, order)
    b = J.sin(a)
    prod = J.mul(a, b)
    refs = ref_derivs(lambda x: x * jnp.sin(x), X0, V, order)
    out = J.derivatives(prod)
    for k in range(order + 1):
        np.testing.assert_allclose(out[k], refs[k], rtol=1e-8, atol=1e-10)


def test_exp_log_roundtrip():
    a = seeded(jnp.abs(X0) + 0.5, V, 6)
    back = J.log(J.exp(a))
    np.testing.assert_allclose(back.coeffs, a.coeffs, rtol=1e-9, atol=1e-9)


def test_div_mul_roundtrip():
    a = seeded(X0, V, 6)
    b = seeded(jnp.abs(X0) + 1.0, -V, 6)
    np.testing.assert_allclose(J.mul(J.div(a, b), b).coeffs, a.coeffs,
                               rtol=1e-9, atol=1e-9)


def test_softmax_jet_matches_jacfwd():
    order = 4
    out = J.derivatives(J.softmax(seeded(X0, V, order), axis=-1))
    refs = ref_derivs(lambda x: jax.nn.softmax(x, -1), X0, V, order)
    for k in range(order + 1):
        np.testing.assert_allclose(out[k], refs[k], rtol=1e-7, atol=1e-10)


def test_attention_block_jet_matches_jacfwd():
    d = 6
    key = jax.random.PRNGKey(7)
    wq, wk, wv = (jax.random.normal(jax.random.fold_in(key, i), (d, d),
                                    jnp.float64) * 0.4 for i in range(3))
    x0 = jax.random.normal(jax.random.fold_in(key, 5), (2, 5, d), jnp.float64)
    v = jax.random.normal(jax.random.fold_in(key, 6), (2, 5, d), jnp.float64)

    def ref(x):
        q, k, val = x @ wq, x @ wk, x @ wv
        p = jax.nn.softmax(jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(d), -1)
        return jnp.einsum("bqk,bkd->bqd", p, val)

    def jet_attn(j):
        q, k, val = J.linear(j, wq), J.linear(j, wk), J.linear(j, wv)
        s = J.scale(J.einsum("bqd,bkd->bqk", q, k), 1.0 / jnp.sqrt(d))
        return J.einsum("bqk,bkd->bqd", J.softmax(s, -1), val)

    order = 3
    out = J.derivatives(jet_attn(J.seed(x0, v, order)))
    refs = ref_derivs(ref, x0, v, order)
    for k in range(order + 1):
        np.testing.assert_allclose(out[k], refs[k], rtol=1e-7, atol=1e-10)


def test_rms_and_layer_norm_jets():
    gam = jnp.full((3,), 1.2, jnp.float64)
    beta = jnp.full((3,), -0.1, jnp.float64)
    order = 4

    def rms_ref(x):
        return x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6) * gam

    def ln_ref(x):
        mu = x.mean(-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(x.var(-1, keepdims=True) + 1e-5) * gam + beta

    for jet_fn, ref_fn in ((lambda j: J.rms_norm(j, gam, offset=0.0), rms_ref),
                           (lambda j: J.layer_norm(j, gam, beta), ln_ref)):
        out = J.derivatives(jet_fn(seeded(X0, V, order)))
        refs = ref_derivs(ref_fn, X0, V, order)
        for k in range(order + 1):
            np.testing.assert_allclose(out[k], refs[k], rtol=1e-6, atol=1e-9)


def test_where_scalar_promotion_regression():
    """Dedicated lock on J.where's scalar-promotion edge (previously only
    exercised through relu / the attention -inf fill inside operator
    sweeps): a non-Jet branch promotes to a constant jet -- value on c_0,
    zeros above -- regardless of side, Python numeric type, or rank."""
    coeffs = jax.random.normal(jax.random.PRNGKey(3), (4, 3, 2), jnp.float64)
    a = J.Jet(coeffs)
    mask = jnp.asarray([[True, False], [False, True], [True, True]])

    out = J.where(mask, a, -30.0)                  # jet, scalar
    np.testing.assert_allclose(out.coeffs[0], jnp.where(mask, coeffs[0], -30.0))
    for k in range(1, 4):                          # constant branch: zeros
        np.testing.assert_allclose(out.coeffs[k], jnp.where(mask, coeffs[k], 0.0))

    flipped = J.where(mask, -30.0, a)              # scalar, jet
    np.testing.assert_allclose(flipped.coeffs[0],
                               jnp.where(mask, -30.0, coeffs[0]))
    np.testing.assert_allclose(flipped.coeffs[2],
                               jnp.where(mask, 0.0, coeffs[2]))

    as_int = J.where(mask, a, 2)                   # Python int follows jet dtype
    assert as_int.dtype == a.dtype
    np.testing.assert_allclose(as_int.coeffs[0], jnp.where(mask, coeffs[0], 2.0))

    # 0-d array and broadcasting row-array branches promote the same way
    np.testing.assert_allclose(
        J.where(mask, a, jnp.asarray(1.5)).coeffs[0],
        jnp.where(mask, coeffs[0], 1.5))
    row = jnp.asarray([1.0, 2.0])
    np.testing.assert_allclose(J.where(mask, a, row).coeffs[0],
                               jnp.where(mask, coeffs[0], row))

    # order-0 jets keep their (single-coefficient) stack
    assert J.where(mask, J.Jet(coeffs[:1]), -1.0).coeffs.shape == (1, 3, 2)

    with pytest.raises(TypeError, match="Jet"):    # no jet operand at all
        J.where(mask, 1.0, 2.0)


@int_grid(("order", 0, 6), max_examples=7)
def test_derivative_roundtrip(order):
    j = seeded(X0, V, order)
    back = J.from_derivatives(J.derivatives(j))
    np.testing.assert_allclose(back.coeffs, j.coeffs, rtol=1e-12, atol=0)


# ---------------------------------------------------------------------------
# algebraic ring/functional identities on random truncated series
# ---------------------------------------------------------------------------

def _random_jet(seed, order, shape=(3, 4)):
    k = jax.random.PRNGKey(seed)
    return J.Jet(jax.random.normal(k, (order + 1,) + shape, jnp.float64) * 0.5)


@int_grid(("order", 1, 6), ("seed", 0, 1000), max_examples=15)
def test_mul_associative_and_commutative(order, seed):
    a, b, c = (_random_jet(seed + i, order) for i in range(3))
    ab_c = J.mul(J.mul(a, b), c)
    a_bc = J.mul(a, J.mul(b, c))
    np.testing.assert_allclose(ab_c.coeffs, a_bc.coeffs, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(J.mul(a, b).coeffs, J.mul(b, a).coeffs,
                               rtol=1e-12, atol=0)


@int_grid(("order", 1, 6), ("seed", 0, 1000), max_examples=15)
def test_mul_distributes_over_add(order, seed):
    a, b, c = (_random_jet(seed + i, order) for i in range(3))
    lhs = J.mul(a, J.add(b, c))
    rhs = J.add(J.mul(a, b), J.mul(a, c))
    np.testing.assert_allclose(lhs.coeffs, rhs.coeffs, rtol=1e-10, atol=1e-12)


@int_grid(("order", 1, 6), ("seed", 0, 1000), max_examples=15)
def test_exp_is_a_homomorphism(order, seed):
    a, b = (_random_jet(seed + i, order) for i in range(2))
    lhs = J.exp(J.add(a, b))
    rhs = J.mul(J.exp(a), J.exp(b))
    np.testing.assert_allclose(lhs.coeffs, rhs.coeffs, rtol=1e-9, atol=1e-10)


@int_grid(("order", 1, 6), ("seed", 0, 1000), max_examples=10)
def test_tanh_double_angle_identity(order, seed):
    """tanh(2a) == 2 tanh(a) / (1 + tanh(a)^2): exercises compose + div + mul
    together against an independent functional identity."""
    a = _random_jet(seed, order)
    lhs = J.tanh(J.scale(a, 2.0))
    t = J.tanh(a)
    rhs = J.div(J.scale(t, 2.0), J.add(J.mul(t, t), 1.0))
    np.testing.assert_allclose(lhs.coeffs, rhs.coeffs, rtol=1e-8, atol=1e-10)


# ---------------------------------------------------------------------------
# masked softmax: fully-masked rows must degrade, never NaN
# ---------------------------------------------------------------------------

@int_grid(("order", 0, 4), ("seed", 0, 1000), max_examples=12)
def test_softmax_fully_masked_rows_are_nan_free(order, seed):
    """A mask row that keeps NOTHING becomes the constant MASK_NEG jet: the
    shift cancels it exactly, so the row degrades to the uniform
    distribution with zero coefficients at every order >= 1 -- finite
    everywhere, including under differentiation -- while live rows stay
    bit-identical to the mask-free softmax on their (unmasked) logits."""
    key = jax.random.PRNGKey(seed)
    coeffs = jax.random.normal(key, (order + 1, 2, 3, 4), jnp.float64) * 2.0
    a = J.Jet(coeffs)
    dead = ((0, 1), (1, 2))
    mask = jnp.ones((2, 3, 4), bool)
    for b, q in dead:
        mask = mask.at[b, q].set(False)

    out = J.softmax(a, axis=-1, mask=mask)
    assert bool(jnp.isfinite(out.coeffs).all())
    for b, q in dead:
        np.testing.assert_array_equal(np.asarray(out.coeffs[0, b, q]), 0.25)
        if order:
            np.testing.assert_array_equal(
                np.asarray(out.coeffs[1:, b, q]), 0.0)
    # probabilities stay normalized on every row, dead ones included
    np.testing.assert_allclose(np.asarray(out.coeffs[0].sum(-1)), 1.0,
                               rtol=1e-12)
    # rows the mask leaves fully live are untouched by the mask machinery
    ref = J.softmax(a, axis=-1)
    live = [(b, q) for b in range(2) for q in range(3) if (b, q) not in dead]
    for b, q in live:
        np.testing.assert_array_equal(np.asarray(out.coeffs[:, b, q]),
                                      np.asarray(ref.coeffs[:, b, q]))
    # differentiation THROUGH the masked softmax stays finite too (the
    # MASK_NEG constant-jet substitution is grad-safe, unlike a true -inf)
    g = jax.grad(lambda c: jnp.sum(
        J.softmax(J.Jet(c), axis=-1, mask=mask).coeffs ** 2))(coeffs)
    assert bool(jnp.isfinite(g).all())


def test_softmax_all_true_mask_is_identity():
    a = _random_jet(7, 3)
    np.testing.assert_array_equal(
        np.asarray(J.softmax(a, axis=-1,
                             mask=jnp.ones(a.coeffs.shape[1:], bool)).coeffs),
        np.asarray(J.softmax(a, axis=-1).coeffs))
