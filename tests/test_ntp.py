"""n-TangentProp (the paper's algorithm) vs three oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, init_mlp, mlp_apply, ntp_derivatives, ntp_grid


@pytest.fixture(scope="module")
def net():
    key = jax.random.PRNGKey(0)
    params = init_mlp(key, 1, 24, 3, 1, dtype=jnp.float64)  # paper's 3x24
    x = jax.random.uniform(jax.random.PRNGKey(1), (9, 1), jnp.float64, -1, 1)
    return params, x


@pytest.mark.parametrize("order", [
    0, 1, 3, 5,
    # order-7 nested autodiff takes ~2 min on CPU; tier-1 keeps order <= 5
    pytest.param(7, marks=pytest.mark.slow)])
def test_matches_nested_autodiff(net, order):
    params, x = net
    ours = ntp_derivatives(params, x, order)
    ref = baselines.nested_autodiff(params, x, order)
    np.testing.assert_allclose(ours, ref, rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("order", [1, 3, 6])
def test_matches_jax_experimental_jet(net, order):
    params, x = net
    ours = ntp_derivatives(params, x, order)
    ref = baselines.jax_jet_derivatives(params, x, order)
    np.testing.assert_allclose(ours, ref, rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("order", [1, 4])
def test_matches_nested_jacfwd(net, order):
    params, x = net
    ours = ntp_derivatives(params, x, order)
    ref = baselines.nested_jacfwd(params, x, order)
    np.testing.assert_allclose(ours, ref, rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("activation", ["tanh", "sigmoid", "sin", "softplus"])
def test_other_activations(net, activation):
    params, x = net
    ours = ntp_derivatives(params, x, 4, activation=activation)
    ref = baselines.nested_autodiff(params, x, 4, activation=activation)
    np.testing.assert_allclose(ours, ref, rtol=1e-8, atol=1e-10)


def test_multi_directional_grid(net):
    key = jax.random.PRNGKey(2)
    params = init_mlp(key, 3, 16, 2, 1, dtype=jnp.float64)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 3), jnp.float64)
    grid = ntp_grid(params, x, 3)  # (d_in, order+1, batch, 1)
    assert grid.shape == (3, 4, 5, 1)
    # axis-0 pure derivative equals the directional derivative along e_0
    v = jnp.zeros_like(x).at[:, 0].set(1.0)
    ref = baselines.nested_autodiff(params, x, 3, tangent=v)
    np.testing.assert_allclose(grid[0], ref, rtol=1e-9, atol=1e-11)


def test_pallas_impl_matches_jnp(net):
    key = jax.random.PRNGKey(0)
    params = init_mlp(key, 1, 24, 3, 1, dtype=jnp.float32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (16, 1), jnp.float32, -1, 1)
    a = ntp_derivatives(params, x, 5, impl="jnp")
    b = ntp_derivatives(params, x, 5, impl="pallas")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_gradients_flow_through_both_impls():
    key = jax.random.PRNGKey(0)
    params = init_mlp(key, 1, 16, 2, 1, dtype=jnp.float32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 1), jnp.float32, -1, 1)

    def loss(p, impl):
        return jnp.sum(ntp_derivatives(p, x, 3, impl=impl)[3] ** 2)

    g1 = jax.grad(lambda p: loss(p, "jnp"))(params)
    g2 = jax.grad(lambda p: loss(p, "pallas"))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-5)


def test_order_zero_is_plain_forward(net):
    params, x = net
    out = ntp_derivatives(params, x, 0)
    np.testing.assert_allclose(out[0], mlp_apply(params, x), rtol=1e-12)


def test_linear_memory_stack_shape(net):
    """The jet stack is (order+1, batch, d_out): O(n M) memory, no M^n graph."""
    params, x = net
    for n in (1, 4, 8):
        assert ntp_derivatives(params, x, n).shape == (n + 1, 9, 1)
