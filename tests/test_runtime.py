"""Fault-tolerance runtime: checkpoint/restart, preemption, stragglers,
gradient compression, checkpoint manager semantics."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.parallel.compression import dequantize_int8, ef_compress, quantize_int8
from repro.runtime import Trainer, TrainerConfig


def quad_problem(tmp_path, total=40, ckpt_every=10):
    target = jnp.asarray([3.0, -1.0])

    @jax.jit
    def step(state, batch):
        params, opt_t = state
        g = jax.grad(lambda p: jnp.sum((p - target) ** 2))(params)
        return (params - 0.05 * g, opt_t + 1), jnp.sum((params - target) ** 2)

    cfg = TrainerConfig(total_steps=total, ckpt_every=ckpt_every,
                        ckpt_dir=str(tmp_path), max_retries=5)
    return cfg, step, target


def test_trainer_clean_run(tmp_path):
    cfg, step, target = quad_problem(tmp_path)
    tr = Trainer(cfg, step, lambda s: None)
    (params, t), rep = tr.run((jnp.zeros(2), jnp.asarray(0)))
    assert rep.steps_run == 40 and rep.restarts == 0
    assert rep.losses[-1] < rep.losses[0]


def test_trainer_recovers_from_injected_failures(tmp_path):
    cfg, step, target = quad_problem(tmp_path)
    boom = {25}

    def injector(s):
        if s in boom:
            boom.clear()          # fail exactly once
            raise RuntimeError("injected node failure")

    tr = Trainer(cfg, step, lambda s: None)
    (params, t), rep = tr.run((jnp.zeros(2), jnp.asarray(0)), fail_injector=injector)
    assert rep.restarts == 1
    # resumed from step 20 checkpoint and completed
    assert rep.steps_run >= 40 - 20
    assert rep.losses[-1] < 0.5


def test_trainer_preemption_checkpoints_and_exits(tmp_path):
    cfg, step, target = quad_problem(tmp_path, total=1000, ckpt_every=100)
    tr = Trainer(cfg, step, lambda s: None)

    calls = {"n": 0}
    orig_batch = lambda s: None

    def batch_fn(s):
        calls["n"] += 1
        if calls["n"] == 7:
            tr.request_preempt()
        return None

    tr.batch_fn = batch_fn
    state, rep = tr.run((jnp.zeros(2), jnp.asarray(0)))
    assert rep.preempted
    assert tr.ckpt.latest_step() is not None  # state saved at the boundary


def test_straggler_watchdog(tmp_path):
    cfg, step, target = quad_problem(tmp_path, total=20)
    slow = {10}
    hits = []

    def batch_fn(s):
        if s in slow:
            time.sleep(0.3)
        return None

    tr = Trainer(cfg, step, batch_fn,
                 straggler_cb=lambda s, dt, ema: hits.append(s))
    tr.run((jnp.zeros(2), jnp.asarray(0)))
    assert hits and hits[0] == 10


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": [jnp.zeros(4), jnp.ones(2)]}
    for step in (10, 20, 30):
        mgr.save(step, tree, blocking=True)
    assert mgr.all_steps() == [20, 30]  # keep=2 garbage-collects step 10
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    back = mgr.restore(30, like)
    for a, b in zip(jax.tree_util.tree_leaves(back), jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(a, b)


def test_ckpt_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.full((128, 128), 7.0)}
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_ckpt_elastic_restore_dtype_cast(tmp_path):
    """Restore maps onto a like-tree with different dtype (elastic restarts
    may change precision policy)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones(4, jnp.float32)}, blocking=True)
    back = mgr.restore(1, {"w": jnp.zeros(4, jnp.bfloat16)})
    assert back["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, scale = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - g))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Accumulated EF-compressed updates converge to the true sum."""
    key = jax.random.PRNGKey(1)
    g_true = jax.random.normal(key, (512,))
    err = jnp.zeros((512,), jnp.bfloat16)
    acc = jnp.zeros((512,))
    steps = 50
    for _ in range(steps):
        q, scale, err = ef_compress(g_true, err)
        acc = acc + dequantize_int8(q, scale)
    # average transmitted gradient ~= true gradient (EF guarantee)
    np.testing.assert_allclose(acc / steps, g_true, atol=2e-2)


def test_compressed_psum_multidevice_if_available(tmp_path):
    """Correctness of the compressed psum under shard_map (skips with 1 dev)."""
    if jax.device_count() < 2:
        pytest.skip("single-device container; covered by test_dryrun_subproc")
