"""Differential-operator subsystem: every registered PDE against three
oracles -- nested-autodiff derivative towers, the manufactured/exact solution
(method of manufactured solutions), and the pallas kernel path -- plus the
polarization identity for mixed partials, now including the 4th-order
Navier-Stokes streamfunction terms and the d_out=2 Gray-Scott system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jet as J
from repro.core.engines import AutodiffEngine, DerivativeEngine, NTPEngine
from repro.core.network import DenseMLP
from repro.core.ntp import cross, init_mlp, mlp_apply
from repro.data.collocation import boundary_grid, eval_grid, sample_box
from repro.pinn import (DerivTable, LossWeights, OperatorRunConfig,
                        autodiff_mixed_partial_fn, burgers_operator,
                        exact_values, get_operator, operator_names, pinn_loss,
                        register, residual_jet, residual_of_fn,
                        residual_values, train_operator)

SCALAR_OPS = ("heat", "wave", "kdv", "allen-cahn", "poisson2d",
              "advection-diffusion", "navier-stokes")
SYSTEM_OPS = ("gray-scott",)
DIFFABLE_OPS = SCALAR_OPS + SYSTEM_OPS          # analytic, jax-differentiable
ALL_OPS = DIFFABLE_OPS + ("burgers",)

ENGINE_SPECS = ("ntp", "ntp/pallas", "autodiff")


def _net_and_pts(name, n=7, dtype=jnp.float64, width=12, depth=3, seed=0):
    op = get_operator(name)
    net = DenseMLP(op.d_in, width, depth, op.d_out)
    params = init_mlp(jax.random.PRNGKey(seed), op.d_in, width, depth,
                      op.d_out, dtype=dtype)
    x = sample_box(jax.random.PRNGKey(seed + 1), op.domain, n, dtype)
    return op, net, params, x


def _exact_fn(op):
    """op.exact as a per-point function: (d_in,) -> () or (d_out,)."""
    return lambda xi: op.exact(xi[None, :])[0]


# ---------------------------------------------------------------------------
# oracle 1: nested autodiff -- the full registry sweep across every engine.
# The autodiff reference residual is the expensive half of each comparison
# (O(M^order) towers, dominated by navier-stokes), so it is computed ONCE
# per (operator, shape) and shared across the engine-spec params instead of
# being rebuilt three times -- coverage is identical, wall clock is not.
# ---------------------------------------------------------------------------

_AUTODIFF_REF_CACHE = {}


def _autodiff_ref(cache_key, op, net, params, x):
    if cache_key not in _AUTODIFF_REF_CACHE:
        _AUTODIFF_REF_CACHE[cache_key] = residual_values(
            params, op, x, net=net, engine="autodiff")
    return _AUTODIFF_REF_CACHE[cache_key]


@pytest.mark.parametrize("spec", ENGINE_SPECS)
@pytest.mark.parametrize("name", ALL_OPS)
def test_registry_sweep_all_engines(name, spec):
    """Acceptance sweep: EVERY registered operator (systems included) runs
    under every engine spec at smoke shapes and matches the nested-autodiff
    oracle.  The pallas path gets float-precision-scale tolerance (its
    kernels accumulate differently), the jnp paths double-precision-scale."""
    op, net, params, x = _net_and_pts(name, n=6, width=8, depth=2)
    ref = _autodiff_ref(("dense", name), op, net, params, x)
    if spec == "autodiff":
        # the reference IS this spec's run (same from_spec code path built
        # the cache); rerunning the tower would only re-time a tautology
        got = ref
    else:
        got = residual_values(params, op, x, net=net,
                              engine=DerivativeEngine.from_spec(spec))
    tol = dict(rtol=2e-5, atol=2e-6) if spec == "ntp/pallas" \
        else dict(rtol=1e-8, atol=1e-9)
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, **tol)


@pytest.mark.parametrize("name", ("heat", "kdv"))
@pytest.mark.parametrize("activation", ("tanh", "sin"))
def test_residual_engines_agree_across_activations(name, activation):
    op, _, params, x = _net_and_pts(name)
    net = DenseMLP(op.d_in, 12, 3, op.d_out, activation=activation)
    ours = residual_values(params, op, x, net=net, engine="ntp")
    ref = residual_values(params, op, x, net=net, engine="autodiff")
    np.testing.assert_allclose(ours, ref, rtol=1e-8, atol=1e-9)


# ---------------------------------------------------------------------------
# oracle 2: manufactured / exact solutions (residual must vanish identically)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", DIFFABLE_OPS)
def test_residual_vanishes_on_exact_solution(name):
    op = get_operator(name)
    assert op.differentiable_exact
    x = sample_box(jax.random.PRNGKey(7), op.domain, 64, jnp.float64)
    r = residual_of_fn(op, _exact_fn(op), x)
    assert float(jnp.max(jnp.abs(r))) < 1e-9


def test_burgers_exact_solution_vanishes_via_finite_differences():
    """Burgers' exact profile is a numpy bisection (not jax-differentiable),
    so certify it through the operator residual with FD derivatives."""
    op = get_operator("burgers")
    xs = np.linspace(-1.5, 1.5, 401)
    u = np.asarray(op.exact(jnp.asarray(xs)[:, None]))
    du = np.gradient(u, xs)
    D = jnp.asarray(np.stack([u, du])[None])          # (1 axis, 2 orders, N)
    r = op.residual(jnp.asarray(xs)[:, None], DerivTable(D))
    assert float(jnp.max(jnp.abs(r[5:-5]))) < 5e-3    # FD error only


def test_burgers_operator_matches_residual_jet():
    """The registered operator computes the same residual as the specialized
    Burgers jet pipeline (losses.burgers_pinn_loss's engine)."""
    op, net, params, x = _net_and_pts("burgers")
    ours = residual_values(params, op, x, net=net, engine="ntp")
    ref = J.derivatives(residual_jet(params, 0.5, x, 1))[0, :, 0]
    np.testing.assert_allclose(ours, ref, rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# the two new systems: Navier-Stokes streamfunction + Gray-Scott
# ---------------------------------------------------------------------------

def test_navier_stokes_consumes_4th_order_polarization():
    """psi_xxyy reaches the residual through a 4th-order polarization cross
    (16 directional order-4 jets) and matches direct nested-grad partials;
    zeroing it must change the residual (the biharmonic genuinely couples)."""
    op, net, params, x = _net_and_pts("navier-stokes", n=5, width=10, depth=2)
    eng = NTPEngine("jnp")
    ours = eng.cross(net, params, x, (0, 0, 1, 1))[:, 0]
    fn = lambda xi: mlp_apply(params, xi[None, :], unroll=True)[0, 0]
    ref = autodiff_mixed_partial_fn(fn, x, (0, 0, 1, 1))
    np.testing.assert_allclose(ours, ref, rtol=1e-7, atol=1e-8)

    from repro.pinn.operators import build_table
    table = build_table(net, params, eng, op, x)
    r_full = op.residual(x, table)
    zeroed = dict(table._mixed)
    zeroed[(0, 0, 1, 1)] = jnp.zeros_like(zeroed[(0, 0, 1, 1)])
    r_nomix = op.residual(x, DerivTable(table._pure, zeroed))
    assert float(jnp.max(jnp.abs(r_full - r_nomix))) > 1e-6


def test_gray_scott_component_axis():
    """The d_out=2 residual reads both fields from one shared table; swapping
    the network's output columns must change both equations."""
    op, net, params, x = _net_and_pts("gray-scott", n=6, width=10, depth=2)
    r = residual_values(params, op, x, net=net, engine="ntp")
    assert r.shape == (2, x.shape[0])
    swapped = params._replace(w_out=params.w_out[:, ::-1],
                              b_out=params.b_out[::-1])
    r_sw = residual_values(swapped, op, x, net=net, engine="ntp")
    assert float(jnp.max(jnp.abs(r - r_sw))) > 1e-6


def test_gray_scott_exact_values_shape():
    op = get_operator("gray-scott")
    x = sample_box(jax.random.PRNGKey(0), op.domain, 9, jnp.float64)
    vals = exact_values(op, x)
    assert vals.shape == (9, 2)
    # scalar operators normalize to a single column
    heat = get_operator("heat")
    xh = sample_box(jax.random.PRNGKey(1), heat.domain, 5, jnp.float64)
    assert exact_values(heat, xh).shape == (5, 1)


@pytest.mark.parametrize("spec", ENGINE_SPECS)
@pytest.mark.parametrize("name", ("heat", "kdv", "gray-scott"))
def test_transformer_trunk_residuals_match_autodiff(name, spec):
    """The attention trunk rides the operator subsystem like every MLP:
    residuals under each engine spec match the nested-autodiff oracle,
    including the d_out=2 system (shared trunk, one output column per
    field).  ntp/pallas runs the FUSED attention-score + rms_norm kernels
    end to end.  The autodiff reference is cache-shared across specs."""
    from repro.core.network import Transformer
    op = get_operator(name)
    net = Transformer(op.d_in, 8, 1, op.d_out, n_heads=2)
    params = net.init(jax.random.PRNGKey(0), dtype=jnp.float64)
    x = sample_box(jax.random.PRNGKey(1), op.domain, 5, jnp.float64)
    ref = _autodiff_ref(("transformer", name), op, net, params, x)
    if spec == "autodiff":
        got = ref
    else:
        got = residual_values(params, op, x, net=net,
                              engine=DerivativeEngine.from_spec(spec))
    tol = dict(rtol=2e-5, atol=2e-6) if spec == "ntp/pallas" \
        else dict(rtol=1e-7, atol=1e-8)
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, **tol)


# ---------------------------------------------------------------------------
# oracle 3: the pallas kernel path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("heat", "kdv", "burgers",
                                  "advection-diffusion", "navier-stokes",
                                  "gray-scott"))
def test_pallas_impl_matches_jnp(name):
    op = get_operator(name)
    net = DenseMLP(op.d_in, 16, 3, op.d_out)
    params = init_mlp(jax.random.PRNGKey(0), op.d_in, 16, 3, op.d_out,
                      dtype=jnp.float32)
    x = sample_box(jax.random.PRNGKey(1), op.domain, 16, jnp.float32)
    a = residual_values(params, op, x, net=net, engine="ntp")
    b = residual_values(params, op, x, net=net, engine="ntp/pallas")
    np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-4)


# ---------------------------------------------------------------------------
# mixed partials: the advection-diffusion cross term + the DerivTable surface
# ---------------------------------------------------------------------------

def test_advection_diffusion_consumes_cross_polarization():
    """The u_xy term reaches the residual through engine.cross (polarization
    of directional jets) and matches a direct nested-grad mixed partial."""
    op, net, params, x = _net_and_pts("advection-diffusion")
    ours = NTPEngine("jnp").cross(net, params, x, (1, 2))[:, 0]
    fn = lambda xi: mlp_apply(params, xi[None, :], unroll=True)[0, 0]
    ref = autodiff_mixed_partial_fn(fn, x, (1, 2))
    np.testing.assert_allclose(ours, ref, rtol=1e-8, atol=1e-10)
    # and the mixed term genuinely contributes to the residual (d12 != 0)
    from repro.pinn.operators import build_table
    table = build_table(net, params, NTPEngine("jnp"), op, x)
    r_full = op.residual(x, table)
    r_nomix = op.residual(x, DerivTable(table._pure,
                                        {(1, 2): jnp.zeros(x.shape[0])}))
    assert float(jnp.max(jnp.abs(r_full - r_nomix))) > 1e-6


def test_deriv_table_comp_out_of_range_regression():
    """Dedicated lock on ``comp=`` bounds checking (previously only hit
    indirectly through system sweeps): every out-of-range component index --
    positive, negative, on pure and mixed lookups, on promoted
    single-component and genuine multi-component tables -- must raise
    IndexError instead of letting jnp's clamping serve the wrong field."""
    single = DerivTable(jnp.zeros((2, 3, 4)), {(0, 1): jnp.zeros(4)})
    two = DerivTable(
        jnp.arange(2 * 3 * 4 * 2, dtype=jnp.float64).reshape(2, 3, 4, 2),
        {(0, 1): jnp.arange(8, dtype=jnp.float64).reshape(4, 2)})
    for table, n_comp in ((single, 1), (two, 2)):
        assert table.n_components == n_comp
        for bad in (n_comp, n_comp + 3, -1):
            with pytest.raises(IndexError, match=f"comp={bad}"):
                table(0, 0, comp=bad)
            with pytest.raises(IndexError, match=f"comp={bad}"):
                table.mixed(0, 1, comp=bad)
    # in-range reads address the exact component (no silent clamping)
    np.testing.assert_allclose(two(1, 2, comp=1), two._pure[1, 2, :, 1])
    np.testing.assert_allclose(two.mixed(1, 0, comp=1), two._mixed[(0, 1)][:, 1])


def test_deriv_table_surface():
    d = DerivTable(jnp.zeros((2, 3, 4)), {(0, 1): jnp.zeros(4)})
    assert d.n_components == 1                       # rank-3 promotes to one
    np.testing.assert_allclose(d.mixed(1, 0), 0.0)   # order-insensitive
    with pytest.raises(KeyError, match="mixed="):
        d.mixed(0, 0)
    # component indexing round-trips
    pure = jnp.arange(2 * 3 * 4 * 2, dtype=jnp.float64).reshape(2, 3, 4, 2)
    mx = jnp.arange(8, dtype=jnp.float64).reshape(4, 2)
    dv = DerivTable(pure, {(0, 1): mx})
    assert dv.n_components == 2
    np.testing.assert_allclose(dv(1, 2, comp=1), pure[1, 2, :, 1])
    np.testing.assert_allclose(dv(1, 2), pure[1, 2, :, 0])  # comp defaults 0
    np.testing.assert_allclose(dv.mixed(0, 1, comp=1), mx[:, 1])
    # out-of-range lookups raise instead of letting jnp clamp to a wrong
    # (but plausible-looking) component/axis/order
    with pytest.raises(IndexError, match="comp=2"):
        dv(0, 0, comp=2)
    with pytest.raises(IndexError, match="comp=1"):
        d(0, 0, comp=1)
    with pytest.raises(IndexError, match="comp=2"):
        dv.mixed(0, 1, comp=2)
    with pytest.raises(IndexError):
        dv(2, 0)                                 # axis beyond d_in
    with pytest.raises(IndexError):
        dv(0, 3)                                 # order beyond the table


# ---------------------------------------------------------------------------
# polarization: cross-recovered mixed partials match autodiff
# ---------------------------------------------------------------------------

def test_cross_polarization_matches_autodiff():
    params = init_mlp(jax.random.PRNGKey(4), 2, 14, 3, 1, dtype=jnp.float64)
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 2), jnp.float64)
    fn = lambda xi: mlp_apply(params, xi[None, :], unroll=True)[0, 0]

    H = jax.vmap(jax.hessian(fn))(x)                        # (N, 2, 2)
    np.testing.assert_allclose(cross(params, x, (0, 1))[:, 0], H[:, 0, 1],
                               rtol=1e-8, atol=1e-10)
    # repeated axes reduce to pure derivatives
    np.testing.assert_allclose(cross(params, x, (1, 1))[:, 0], H[:, 1, 1],
                               rtol=1e-8, atol=1e-10)
    # third-order mixed partial u_xxy
    T3 = jax.vmap(jax.jacfwd(jax.hessian(fn)))(x)           # (N, 2, 2, 2)
    np.testing.assert_allclose(cross(params, x, (0, 0, 1))[:, 0],
                               T3[:, 0, 0, 1], rtol=1e-7, atol=1e-9)


def test_cross_symmetry_of_mixed_partials():
    params = init_mlp(jax.random.PRNGKey(6), 3, 10, 2, 1, dtype=jnp.float64)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 3), jnp.float64)
    np.testing.assert_allclose(cross(params, x, (0, 2)), cross(params, x, (2, 0)),
                               rtol=1e-9, atol=1e-11)
    with pytest.raises(ValueError):
        cross(params, x, ())
    with pytest.raises(ValueError):
        cross(params, x, (0, 5))   # out-of-range axis must not silently clamp


# ---------------------------------------------------------------------------
# generic loss + trainer surface
# ---------------------------------------------------------------------------

# Loss-level engine agreement runs on a structurally representative subset:
# heat (scalar), advection-diffusion (d_in=3 + a genuine mixed partial),
# gray-scott (d_out=2 system).  The loss assembles the SAME derivative
# table as residual_values, and the full operator x engine matrix stays
# oracle-gated at the residual level by test_registry_sweep_all_engines --
# repeating every O(M^4) navier-stokes autodiff tower at the loss level
# bought only tier-1 minutes (the systems still train e2e below).
LOSS_STRUCTURAL_OPS = ("heat", "advection-diffusion", "gray-scott")


@pytest.mark.parametrize("name", LOSS_STRUCTURAL_OPS)
def test_generic_loss_engines_agree(name):
    op, net, params, x = _net_and_pts(name, n=16, width=10, depth=2)
    bc = boundary_grid(op.domain, 6, jnp.float64)
    bc_vals = exact_values(op, bc)
    kw = dict(op=op, pts=x, bc_pts=bc, bc_vals=bc_vals, net=net,
              weights=LossWeights())
    l1, aux1 = pinn_loss(params, engine="ntp", **kw)
    l2, aux2 = pinn_loss(params, engine="autodiff", **kw)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-9)
    assert set(aux1) == {"residual", "bc"}
    # accepts the operator by name too
    l3, _ = pinn_loss(params, engine="ntp", **{**kw, "op": name})
    np.testing.assert_allclose(float(l1), float(l3), rtol=1e-12)


@pytest.mark.parametrize("name", LOSS_STRUCTURAL_OPS + ("burgers",))
def test_loss_identical_across_all_engine_objects(name):
    """The structural subset (plus burgers' non-differentiable-exact path)
    produces the same loss under NTPEngine('jnp'), NTPEngine('pallas'), and
    AutodiffEngine() through the object API, and the spec-string path agrees
    bit-for-bit with the object path."""
    op = get_operator(name)
    net = DenseMLP(op.d_in, 10, 2, op.d_out)
    params = init_mlp(jax.random.PRNGKey(2), op.d_in, 10, 2, op.d_out,
                      dtype=jnp.float32)
    x = sample_box(jax.random.PRNGKey(3), op.domain, 12, jnp.float32)
    bc = boundary_grid(op.domain, 4, jnp.float32)
    bc_vals = exact_values(op, bc, jnp.float32)
    kw = dict(op=op, pts=x, bc_pts=bc, bc_vals=bc_vals, net=net,
              weights=LossWeights())
    l_jnp = float(pinn_loss(params, engine=NTPEngine("jnp"), **kw)[0])
    l_pal = float(pinn_loss(params, engine=NTPEngine("pallas"), **kw)[0])
    l_ad = float(pinn_loss(params, engine=AutodiffEngine(), **kw)[0])
    l_spec = float(pinn_loss(params, engine="ntp", **kw)[0])
    np.testing.assert_allclose(l_jnp, l_ad, rtol=2e-4)
    np.testing.assert_allclose(l_jnp, l_pal, rtol=2e-2)
    np.testing.assert_allclose(l_jnp, l_spec, rtol=0, atol=0)


def test_generic_loss_is_jit_and_grad_compatible():
    op, net, params, x = _net_and_pts("heat", n=8, width=8, depth=2)
    bc = boundary_grid(op.domain, 4, jnp.float64)
    bc_vals = exact_values(op, bc)

    @jax.jit
    def loss(p):
        return pinn_loss(p, op=op, pts=x, bc_pts=bc, bc_vals=bc_vals,
                         net=net)[0]

    g = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(g))


def test_system_loss_is_jit_and_grad_compatible():
    """The d_out=2 objective differentiates cleanly end to end."""
    op, net, params, x = _net_and_pts("gray-scott", n=8, width=8, depth=2)
    bc = boundary_grid(op.domain, 4, jnp.float64)
    bc_vals = exact_values(op, bc)

    @jax.jit
    def loss(p):
        return pinn_loss(p, op=op, pts=x, bc_pts=bc, bc_vals=bc_vals,
                         net=net)[0]

    g = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(g))


def test_registry_surface():
    for name in ALL_OPS:
        assert name in operator_names()
    with pytest.raises(KeyError):
        get_operator("navier-stokes-3d")
    with pytest.raises(ValueError):
        register(burgers_operator())  # duplicate name


def test_boundary_and_eval_grids():
    op = get_operator("poisson2d")
    bc = boundary_grid(op.domain, 9, jnp.float64)
    assert bc.shape == (4 * 9, 2)
    lo, hi = 0.0, float(np.pi)
    on_face = (jnp.isclose(bc, lo) | jnp.isclose(bc, hi)).any(axis=1)
    assert bool(on_face.all())
    # exact Poisson solution is zero on the whole boundary
    np.testing.assert_allclose(np.asarray(op.exact(bc)), 0.0, atol=1e-12)
    ge = eval_grid(op.domain, 5)
    assert ge.shape == (25, 2)


def test_train_operator_smoke(trained_operator):
    cfg = OperatorRunConfig(op="heat", width=8, depth=2, adam_steps=4,
                            n_domain=32, n_bc=8, log_every=2,
                            eval_pts_per_axis=8)
    res = trained_operator(cfg)
    assert res.op_name == "heat"
    assert np.isfinite(res.l2_error)
    assert len(res.loss_history) >= 2


@pytest.mark.parametrize("engine", ("ntp", "ntp/pallas"))
@pytest.mark.parametrize("name", ("gray-scott", "navier-stokes"))
def test_new_systems_train_end_to_end(name, engine, trained_operator):
    """Acceptance: both new systems train end to end under ntp/jnp AND
    ntp/pallas -- the d_out=2 network and the 4th-order streamfunction
    operator run the full pinn_loss/train_operator path."""
    cfg = OperatorRunConfig(op=name, engine=engine, width=8, depth=2,
                            adam_steps=3, n_domain=16, n_bc=4, log_every=1,
                            eval_pts_per_axis=5)
    res = trained_operator(cfg)
    assert res.op_name == name
    assert np.isfinite(res.l2_error)
    assert all(np.isfinite(v) for v in res.loss_history)


@pytest.mark.slow
@pytest.mark.parametrize("name", ("poisson2d", "heat"))
def test_operator_training_converges(name):
    cfg = OperatorRunConfig(op=name, width=24, depth=3, adam_steps=1200,
                            adam_lr=3e-3, n_domain=512, n_bc=48,
                            log_every=200, eval_pts_per_axis=24)
    res = train_operator(cfg)
    assert res.loss_history[-1] < res.loss_history[0] * 1e-2
    assert res.l2_error < 0.15


@pytest.mark.slow
def test_operator_training_autodiff_engine_converges_too():
    cfg = OperatorRunConfig(op="poisson2d", engine="autodiff", width=16,
                            depth=2, adam_steps=600, adam_lr=3e-3,
                            n_domain=256, n_bc=32, log_every=200,
                            eval_pts_per_axis=16)
    res = train_operator(cfg)
    assert res.loss_history[-1] < res.loss_history[0] * 1e-1


@pytest.mark.slow
def test_gray_scott_training_converges():
    """The coupled system actually learns both manufactured fields."""
    cfg = OperatorRunConfig(op="gray-scott", width=24, depth=3,
                            adam_steps=1200, adam_lr=3e-3, n_domain=512,
                            n_bc=48, log_every=200, eval_pts_per_axis=24)
    res = train_operator(cfg)
    assert res.loss_history[-1] < res.loss_history[0] * 1e-2
    assert res.l2_error < 0.15
