"""PINN substrate: Burgers residual jets, exact profiles, mini end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jet as J
from repro.core.ntp import init_mlp
from repro.pinn import (PINNRunConfig, exact_profile, lambda_window,
                        profile_lambda, residual_derivs_autodiff, residual_jet,
                        smoothness_order, train)


def test_profile_constants():
    assert profile_lambda(1) == 0.5
    assert lambda_window(1) == (1 / 3, 1.0)
    assert smoothness_order(2) == 5


def test_exact_profile_roundtrip():
    xs = np.linspace(-2, 2, 41)
    for k in (1, 2, 3):
        u = exact_profile(xs, k)
        np.testing.assert_allclose(-u - u ** (2 * k + 1), xs, atol=1e-10)
        # odd function
        np.testing.assert_allclose(u, -u[::-1], atol=1e-10)


@pytest.mark.parametrize("order", [
    1, 3, 5,
    # the order-7 nested-autodiff oracle alone costs minutes on CPU (the
    # O(M^n) blowup the paper removes) -- keep it, but out of tier-1
    pytest.param(7, marks=pytest.mark.slow)])
def test_residual_jet_matches_autodiff(order):
    params = init_mlp(jax.random.PRNGKey(0), 1, 24, 3, 1, dtype=jnp.float64)
    x = jnp.linspace(-1, 1, 7, dtype=jnp.float64)[:, None]
    ours = J.derivatives(residual_jet(params, 0.5, x, order))
    ref = residual_derivs_autodiff(params, 0.5, x, order)
    np.testing.assert_allclose(ours, ref, rtol=1e-8, atol=1e-10)


def test_residual_zero_on_exact_solution():
    """R evaluated through the jets vanishes on the closed-form profile: wire
    the exact U into a 'network' by fitting... instead check directly with a
    polynomial-free approach: finite-difference the implicit solution."""
    xs = np.linspace(-1.5, 1.5, 201)
    u = exact_profile(xs, 1)  # lam = 1/2
    du = np.gradient(u, xs)
    r = -0.5 * u + (1.5 * xs + u) * du
    assert np.max(np.abs(r[5:-5])) < 5e-3  # FD error only


@pytest.mark.slow
def test_mini_burgers_training_converges_toward_lambda():
    cfg = PINNRunConfig(k=1, adam_steps=200, lbfgs_steps=40, n_domain=128,
                        n_origin=32, log_every=100)
    res = train(cfg)
    # full runs converge to 0.5; the mini run must at least enter the
    # neighborhood from the window midpoint (0.667 -> toward 0.5)
    assert abs(res.lam - 0.5) < 0.12
    assert res.loss_history[-1] < res.loss_history[0] * 1e-2


def test_engines_share_loss_surface():
    """ntp and autodiff engines compute the same loss (paper: exact method)."""
    from repro.pinn.losses import LossWeights, bc_targets, burgers_pinn_loss

    params = init_mlp(jax.random.PRNGKey(0), 1, 16, 2, 1, dtype=jnp.float64)
    pts = jnp.linspace(-1, 1, 16, dtype=jnp.float64)[:, None]
    opts = jnp.linspace(-0.1, 0.1, 8, dtype=jnp.float64)[:, None]
    kw = dict(k=1, pts=pts, origin_pts=opts, domain=1.0, order=3,
              weights=LossWeights(), lam_window=(1 / 3, 1.0),
              bc_vals=bc_targets(1, 1.0))
    l1, _ = burgers_pinn_loss(params, jnp.zeros(()), engine="ntp", **kw)
    l2, _ = burgers_pinn_loss(params, jnp.zeros(()), engine="autodiff", **kw)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-9)


def test_burgers_loss_respects_activation():
    """The boundary term used to silently fall back to tanh regardless of the
    configured activation; a sin-activated net must yield a different loss."""
    from repro.pinn.losses import LossWeights, bc_targets, burgers_pinn_loss

    params = init_mlp(jax.random.PRNGKey(3), 1, 16, 2, 1, dtype=jnp.float64)
    pts = jnp.linspace(-1, 1, 16, dtype=jnp.float64)[:, None]
    opts = jnp.linspace(-0.1, 0.1, 8, dtype=jnp.float64)[:, None]
    kw = dict(k=1, pts=pts, origin_pts=opts, domain=1.0, order=3,
              weights=LossWeights(), lam_window=(1 / 3, 1.0),
              bc_vals=bc_targets(1, 1.0))
    l_tanh, _ = burgers_pinn_loss(params, jnp.zeros(()), activation="tanh", **kw)
    l_sin, _ = burgers_pinn_loss(params, jnp.zeros(()), activation="sin", **kw)
    assert not np.isclose(float(l_tanh), float(l_sin))
    # and the sin-activated loss agrees across engines (activation threaded
    # consistently through every term, boundary included)
    l_sin_ad, _ = burgers_pinn_loss(params, jnp.zeros(()), activation="sin",
                                    engine="autodiff", **kw)
    np.testing.assert_allclose(float(l_sin), float(l_sin_ad), rtol=1e-9)
