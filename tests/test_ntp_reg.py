"""The jet-Sobolev LM regularizer: exactness of transformer jets vs jacfwd."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import jet as J
from repro.launch.ntp_reg import _f32, _jet_attn, _jet_mlp, jet_forward_dense, \
    ntp_smoothness
from repro.models import init_model
from repro.models.layers import embed
from repro.models.transformer import _pattern_at


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_arch("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float64")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    return cfg, params, toks


def _primal_forward(params, cfg, x):
    """Plain (order-0) forward through the same block math: an order-0 jet is
    literally the standard computation, so nested jacfwd through THIS function
    is an independent oracle for orders >= 1."""
    g = cfg.group
    layers = params["stack"]["groups"]["layers"]

    def group_body(coeffs, gparams):
        xx = J.Jet(coeffs)
        for j in range(g):
            lp = gparams["layers"][j]
            window = cfg.window if _pattern_at(cfg, j) == "local" else None
            h = J.rms_norm(xx, lp["ln1"].astype(x.dtype), offset=1.0)
            xx = J.add(xx, _jet_attn(_f32(lp["attn"]), cfg, h, window))
            h = J.rms_norm(xx, lp["ln2"].astype(x.dtype), offset=1.0)
            xx = J.add(xx, _jet_mlp(_f32(lp["ffn"]), cfg, h))
        return xx.coeffs, None

    coeffs, _ = jax.lax.scan(group_body, x[None], {"layers": _f32(layers)})
    out = J.rms_norm(J.Jet(coeffs), params["final_norm"].astype(x.dtype), offset=1.0)
    return out.coeffs[0]


def test_transformer_jet_matches_jacfwd(dense_setup):
    cfg, params, toks = dense_setup
    order = 3
    x0 = embed(params["embed"], toks, cfg).astype(jnp.float64)
    v = jax.random.normal(jax.random.PRNGKey(2), x0.shape, jnp.float64) * 0.1

    ours = J.derivatives(jet_forward_dense(params, cfg, toks, order, direction=v))

    h = lambda t: _primal_forward(params, cfg, x0 + t * v)
    for k in range(order + 1):
        ref = h(0.0)
        np.testing.assert_allclose(np.asarray(ours[k]), np.asarray(ref),
                                   rtol=1e-6, atol=1e-8, err_msg=f"order {k}")
        h = jax.jacfwd(h)


def test_ntp_smoothness_scalar_and_grad(dense_setup):
    cfg, params, toks = dense_setup
    val = ntp_smoothness(params, cfg, {"tokens": toks}, 2)
    assert np.isfinite(float(val)) and float(val) >= 0
    g = jax.grad(lambda p: ntp_smoothness(p, cfg, {"tokens": toks}, 2))(params)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_rejects_non_dense():
    cfg = get_arch("rwkv6-3b").reduced()
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        jet_forward_dense(params, cfg, jnp.zeros((1, 4), jnp.int32), 2)
