"""Multi-device integration tests, run in subprocesses with forced host
devices (the main test process must keep the default 1-device jax, so
anything needing a mesh gets its own interpreter with XLA_FLAGS set first).

The whole module is ``multidevice``-marked: deselected from tier-1 (each
test spins its own interpreter, tier-1 shouldn't pay that repeatedly) and
run as its own CI job.  ``run_py(code, devices=N)`` is the one helper every
mesh-shape sweep parametrizes -- tests/test_jet_shard.py reuses it for the
sharded-jet parity layer."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 480) -> str:
    """Run ``code`` in a fresh interpreter with ``devices`` forced host
    devices; asserts a zero exit and returns the child's stdout.  On
    failure the assertion surfaces BOTH streams -- a child that fails an
    assert after printing diagnostics puts the story in stdout, not just
    the traceback in stderr."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}").strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, (
        f"child exited {out.returncode}\n"
        f"--- stdout (last 4000) ---\n{out.stdout[-4000:]}\n"
        f"--- stderr (last 4000) ---\n{out.stderr[-4000:]}")
    return out.stdout


def test_small_mesh_train_step_runs():
    """A real (executed, not just compiled) sharded train step on a 4x2 mesh."""
    print(run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.configs.base import ShapeCfg
        from repro.launch.sharding import build_train_step
        from repro.data.tokens import synthetic_batch
        from repro.models import init_model
        from repro.optim import adam_init

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_arch("qwen3-0.6b").reduced()
        shape = ShapeCfg("t", 32, 8, "train")
        built = build_train_step(cfg, mesh, shape, fsdp=False)
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        opt = adam_init(params)
        batch = synthetic_batch(cfg, shape, 0)
        with mesh:
            p2, o2, loss, m = built.fn(params, opt, batch)
            p3, o3, loss2, m = built.fn(p2, o2, synthetic_batch(cfg, shape, 1))
        assert jnp.isfinite(loss) and jnp.isfinite(loss2), (loss, loss2)
        print("loss", float(loss), "->", float(loss2))
    """))


def test_small_mesh_serve_step_runs():
    print(run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.configs.base import ShapeCfg
        from repro.launch.sharding import build_serve_step
        from repro.models import init_model, decode_state_specs

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_arch("rwkv6-3b").reduced()
        shape = ShapeCfg("d", 32, 8, "decode")
        built = build_serve_step(cfg, mesh, shape)
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        st = decode_state_specs(cfg, 8, 32, abstract=False)
        with mesh:
            lg, st2 = built.fn(params, jnp.zeros((8, 1), jnp.int32), st)
        assert jnp.isfinite(lg.astype(jnp.float32)).all()
        print("decode ok", lg.shape)
    """))


def test_dryrun_lower_compile_small_mesh():
    """The dry-run machinery end-to-end on an 8-device version of the mesh."""
    print(run_py("""
        import jax
        from repro.configs import get_arch, SHAPES
        from repro.configs.base import ShapeCfg
        from repro.launch import sharding as shd
        from repro.launch.hlo_static import analyze

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_arch("granite-3-2b").reduced()
        shape = ShapeCfg("t", 64, 8, "train")
        built = shd.build_train_step(cfg, mesh, shape, fsdp=True)
        with mesh:
            lowered = built.fn.lower(*built.arg_specs)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        totals = analyze(compiled.as_text())
        assert totals.flops > 0
        print("flops", totals.flops, "coll", totals.total_collective_bytes)
    """))


def test_compressed_psum_matches_fp32():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.compression import compressed_psum_tree, ef_init

        mesh = jax.make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 2.0
        err = jnp.zeros((8, 64), jnp.bfloat16)

        @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
                 out_specs=(P("pod"), P("pod")))
        def red(g, e):
            out, e2 = compressed_psum_tree({"g": g}, {"g": e}, "pod")
            return out["g"], e2["g"]

        got, err2 = red(g, err)
        want = jnp.sum(g, 0, keepdims=True)  # psum replicates the sum
        rel = float(jnp.max(jnp.abs(got[0] - want[0])) / jnp.max(jnp.abs(want)))
        assert rel < 0.02, rel
        print("compressed psum rel err", rel)
    """))


def test_gpipe_matches_sequential():
    """GPipe microbatch schedule == sequential stage application (4 stages)."""
    print(run_py("""
        import jax, jax.numpy as jnp
        from repro.runtime.pipeline import gpipe

        mesh = jax.make_mesh((4,), ("stage",))
        W = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.2
        stage = lambda p, x: x + jnp.tanh(x @ p["w"])
        xs = jax.random.normal(jax.random.PRNGKey(1), (6, 8, 16))
        with mesh:
            y = gpipe(stage, mesh)({"w": W}, xs)
        ref = xs
        for s in range(4):
            ref = jax.vmap(lambda mb: stage({"w": W[s]}, mb))(ref)
        err = float(jnp.max(jnp.abs(y - ref)))
        assert err < 1e-5, err
        print("gpipe exact:", err)
    """, devices=4))


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written under a 4x2 mesh restores onto 2x4 (elastic)."""
    print(run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import CheckpointManager

        w = jnp.arange(64.0).reshape(8, 8)
        m1 = jax.make_mesh((4, 2), ("data", "model"))
        t1 = jax.device_put(w, NamedSharding(m1, P("data", "model")))
        mgr = CheckpointManager(r"{tmp_path}")
        mgr.save(7, {{"w": t1}}, blocking=True)

        m2 = jax.make_mesh((2, 4), ("data", "model"))
        sh2 = {{"w": NamedSharding(m2, P("model", "data"))}}
        back = mgr.restore(7, {{"w": jnp.zeros((8, 8))}}, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(back["w"]), np.arange(64.0).reshape(8, 8))
        print("elastic restore ok", back["w"].sharding)
    """))
