"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Fast mode keeps CPU wall time sane;
pass --full for the paper-scale grids, --smoke for the CI completeness check
(tiny shapes, one trial -- benchmark code must at least *run* on every PR so
it cannot rot uncollected).  ``--json PATH`` additionally writes the rows as
structured records (suite, name, us_per_call, mode, derived) -- the CI
tier-1 job uploads that file as a ``BENCH_*.json`` artifact on every commit
so the perf trajectory is machine-readable, and ``benchmarks/compare.py``
gates PRs on its coverage against ``benchmarks/baseline_smoke.json``.

  PYTHONPATH=src python -m benchmarks.run [--full|--smoke] [--only NAME]
                                          [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

BENCH_SCHEMA_VERSION = 1


def parse_row(suite: str, mode: str, row: str) -> dict:
    """One ``name,us_per_call,derived`` CSV line -> a structured record."""
    name, us, derived = row.split(",", 2)
    return {"suite": suite, "name": name, "us_per_call": float(us),
            "mode": mode, "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single trial; used by the CI tier-1 "
                         "job to keep benchmark code importable and runnable")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write structured results (suite, name, "
                         "us_per_call, mode, derived) to PATH")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")

    from . import (burgers_e2e, fwd_bwd, memory_scaling, operators_bench,
                   partition_growth, ratio_grid, roofline, serving_bench)

    mode = "smoke" if args.smoke else ("full" if args.full else "fast")
    # one entry per suite: (runner, {mode: kwargs}) -- a new suite added here
    # is automatically part of the CI --smoke completeness check
    registry = {
        "partition_growth": (partition_growth.run, {
            "smoke": dict(max_order=8), "fast": dict(max_order=16),
            "full": dict(max_order=16)}),
        "fwd_bwd": (fwd_bwd.run, {
            "smoke": dict(max_order=3, trials=1),
            "fast": dict(max_order=5, trials=3),
            "full": dict(max_order=8, trials=5)}),
        "ratio_grid": (ratio_grid.run, {
            "smoke": dict(trials=1), "fast": dict(trials=2),
            "full": dict(trials=3)}),
        "memory_scaling": (memory_scaling.run, {
            "smoke": dict(max_order=4), "fast": dict(max_order=6),
            "full": dict(max_order=6)}),
        "operators": (operators_bench.run, {
            # smoke carries the network axis (residual + transformer on the
            # representative op) so every registered trunk stays coverage-
            # gated per commit, like every operator x engine pair
            "smoke": dict(n_pts=16, width=8, depth=2, trials=1,
                          include_pallas=True,
                          network_axis=operators_bench.NETWORK_AXIS),
            "fast": dict(n_pts=256, trials=2, include_pallas=False),
            "full": dict(n_pts=1024, trials=5, include_pallas=True,
                         network_axis=operators_bench.NETWORK_AXIS)}),
        "serving": (serving_bench.run, {
            # rate axis (RATES) is mode-independent so row names -- and the
            # compare.py coverage gate derived from them -- stay stable
            mode_key: dict(kw) for mode_key, kw
            in serving_bench.MODE_KWARGS.items()}),
        "burgers_e2e": (burgers_e2e.run, {
            "smoke": dict(adam_steps=4, lbfgs_steps=2),
            "fast": dict(adam_steps=40, lbfgs_steps=8),
            "full": dict(adam_steps=200, lbfgs_steps=40)}),
        "roofline": (roofline.run, {"smoke": {}, "fast": {}, "full": {}}),
    }
    if args.only and args.only not in registry:
        ap.error(f"unknown suite {args.only!r}; known: "
                 f"{', '.join(sorted(registry))}")
    suites = {name: (lambda fn=fn, kw=kws[mode]: fn(**kw))
              for name, (fn, kws) in registry.items()}
    print("name,us_per_call,derived")
    records = []
    failed_suites = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(row)
                sys.stdout.flush()
                records.append(parse_row(name, mode, row))
        except Exception:
            traceback.print_exc()
            failed_suites.append(name)

    if args.json:
        payload = {
            "schema_version": BENCH_SCHEMA_VERSION,
            "mode": mode,
            "only": args.only,
            "failed_suites": failed_suites,
            "results": records,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {len(records)} records to {args.json}", file=sys.stderr)

    sys.exit(1 if failed_suites else 0)


if __name__ == "__main__":
    main()
