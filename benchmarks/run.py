"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Fast mode keeps CPU wall time sane;
pass --full for the paper-scale grids.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (burgers_e2e, fwd_bwd, memory_scaling, operators_bench,
                   partition_growth, ratio_grid, roofline)

    suites = {
        "partition_growth": lambda: partition_growth.run(16),
        "fwd_bwd": lambda: fwd_bwd.run(max_order=8 if args.full else 5,
                                       trials=5 if args.full else 3),
        "ratio_grid": lambda: ratio_grid.run(trials=3 if args.full else 2),
        "memory_scaling": lambda: memory_scaling.run(6),
        "operators": lambda: operators_bench.run(
            n_pts=1024 if args.full else 256,
            trials=5 if args.full else 2,
            include_pallas=args.full),
        "burgers_e2e": lambda: burgers_e2e.run(
            adam_steps=200 if args.full else 40,
            lbfgs_steps=40 if args.full else 8),
        "roofline": roofline.run,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(row)
                sys.stdout.flush()
        except Exception:
            traceback.print_exc()
            failed += 1
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
