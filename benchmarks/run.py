"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Fast mode keeps CPU wall time sane;
pass --full for the paper-scale grids, --smoke for the CI completeness check
(tiny shapes, one trial -- benchmark code must at least *run* on every PR so
it cannot rot uncollected).

  PYTHONPATH=src python -m benchmarks.run [--full|--smoke] [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single trial; used by the CI tier-1 "
                         "job to keep benchmark code importable and runnable")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")

    from . import (burgers_e2e, fwd_bwd, memory_scaling, operators_bench,
                   partition_growth, ratio_grid, roofline)

    mode = "smoke" if args.smoke else ("full" if args.full else "fast")
    # one entry per suite: (runner, {mode: kwargs}) -- a new suite added here
    # is automatically part of the CI --smoke completeness check
    registry = {
        "partition_growth": (partition_growth.run, {
            "smoke": dict(max_order=8), "fast": dict(max_order=16),
            "full": dict(max_order=16)}),
        "fwd_bwd": (fwd_bwd.run, {
            "smoke": dict(max_order=3, trials=1),
            "fast": dict(max_order=5, trials=3),
            "full": dict(max_order=8, trials=5)}),
        "ratio_grid": (ratio_grid.run, {
            "smoke": dict(trials=1), "fast": dict(trials=2),
            "full": dict(trials=3)}),
        "memory_scaling": (memory_scaling.run, {
            "smoke": dict(max_order=4), "fast": dict(max_order=6),
            "full": dict(max_order=6)}),
        "operators": (operators_bench.run, {
            "smoke": dict(n_pts=16, width=8, depth=2, trials=1,
                          include_pallas=True),
            "fast": dict(n_pts=256, trials=2, include_pallas=False),
            "full": dict(n_pts=1024, trials=5, include_pallas=True)}),
        "burgers_e2e": (burgers_e2e.run, {
            "smoke": dict(adam_steps=4, lbfgs_steps=2),
            "fast": dict(adam_steps=40, lbfgs_steps=8),
            "full": dict(adam_steps=200, lbfgs_steps=40)}),
        "roofline": (roofline.run, {"smoke": {}, "fast": {}, "full": {}}),
    }
    suites = {name: (lambda fn=fn, kw=kws[mode]: fn(**kw))
              for name, (fn, kws) in registry.items()}
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(row)
                sys.stdout.flush()
        except Exception:
            traceback.print_exc()
            failed += 1
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
