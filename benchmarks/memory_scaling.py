"""Paper section III-B memory claim: n-TangentProp is O(n M) while nested
autodiff's graph is O(M^n).  Measured here as compiled temp-buffer bytes from
XLA's memory analysis (no wall clock needed)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines, init_mlp, ntp_derivatives

from .common import csv_row


def _temp_bytes(fn, *args) -> int:
    mem = jax.jit(fn).lower(*args).compile().memory_analysis()
    return int(getattr(mem, "temp_size_in_bytes", 0) or 0)


def run(max_order: int = 6, batch: int = 256):
    key = jax.random.PRNGKey(0)
    params = init_mlp(key, 1, 24, 3, 1, dtype=jnp.float32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (batch, 1), jnp.float32, -1, 1)
    rows = []
    for n in (1, 2, 4, max_order):
        m_ntp = _temp_bytes(lambda p, x, n=n: ntp_derivatives(p, x, n), params, x)
        m_ad = _temp_bytes(lambda p, x, n=n: baselines.nested_jacfwd(p, x, n),
                           params, x)
        rows.append(csv_row(f"membytes_ntp_n{n}", m_ntp / 1e6,
                            f"bytes={m_ntp}"))
        rows.append(csv_row(f"membytes_autodiff_n{n}", m_ad / 1e6,
                            f"bytes={m_ad};ratio={m_ad / max(m_ntp, 1):.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
