"""Paper section III-B memory claim: n-TangentProp is O(n M) while nested
autodiff's graph is O(M^n).  Measured here as compiled temp-buffer bytes from
XLA's memory analysis (no wall clock needed).

The second sweep makes the flash-attention memory claim the same way: the
PR-5 materializing score kernel's temp footprint grows with T^2 (it holds
the whole (n+1, B*H, T, T) probability jet), while the tiled flash-jet
kernel's grows with its BLOCK sizes -- at fixed T, halving block_q/block_k
shrinks it; at fixed blocks, growing T leaves the per-tile working set
unchanged (only the linear-in-T output remains)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import baselines, init_mlp, ntp_derivatives

from .common import csv_row


def _temp_bytes(fn, *args) -> int:
    mem = jax.jit(fn).lower(*args).compile().memory_analysis()
    return int(getattr(mem, "temp_size_in_bytes", 0) or 0)


def _attention_rows(order: int):
    """Flash-jet vs materializing attention temp bytes, T x block sweep."""
    from repro.kernels.jet_attention import (jet_attention_scores_pallas,
                                             jet_flash_attention_pallas)

    n1, bsz, heads, dh, dm = order + 1, 2, 2, 8, 16
    interpret = jax.default_backend() != "tpu"
    scale = 1.0 / math.sqrt(dh)
    rows = []
    for t in (64, 256):
        kq = jax.random.PRNGKey(t)
        q, k, v = (jax.random.normal(kk, (n1, bsz, heads, t, dh), jnp.float32)
                   for kk in jax.random.split(kq, 3))
        wo = jax.random.normal(jax.random.PRNGKey(1), (heads, dh, dm),
                               jnp.float32)
        m_scores = _temp_bytes(
            lambda qq, kk: jet_attention_scores_pallas(
                qq, kk, scale, interpret=interpret),
            q.reshape(n1, bsz * heads, t, dh), k.reshape(n1, bsz * heads, t, dh))
        rows.append(csv_row(f"membytes_attn_scores_T{t}", m_scores / 1e6,
                            f"bytes={m_scores};order={order};flash=0"))
        for bq in (32, 64):
            m_flash = _temp_bytes(
                lambda qq, kk, vv, ww, bq=bq: jet_flash_attention_pallas(
                    qq, kk, vv, ww, scale, block_q=bq, block_k=bq,
                    interpret=interpret), q, k, v, wo)
            rows.append(csv_row(
                f"membytes_attn_flash_T{t}_blk{bq}", m_flash / 1e6,
                f"bytes={m_flash};order={order};flash=1;"
                f"vs_scores_x={m_flash / max(m_scores, 1):.3f}"))
    return rows


def run(max_order: int = 6, batch: int = 256, attn_order: int = 2):
    key = jax.random.PRNGKey(0)
    params = init_mlp(key, 1, 24, 3, 1, dtype=jnp.float32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (batch, 1), jnp.float32, -1, 1)
    rows = []
    for n in (1, 2, 4, max_order):
        m_ntp = _temp_bytes(lambda p, x, n=n: ntp_derivatives(p, x, n), params, x)
        m_ad = _temp_bytes(lambda p, x, n=n: baselines.nested_jacfwd(p, x, n),
                           params, x)
        rows.append(csv_row(f"membytes_ntp_n{n}", m_ntp / 1e6,
                            f"bytes={m_ntp}"))
        rows.append(csv_row(f"membytes_autodiff_n{n}", m_ad / 1e6,
                            f"bytes={m_ad};ratio={m_ad / max(m_ntp, 1):.2f}"))
    rows.extend(_attention_rows(attn_order))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
