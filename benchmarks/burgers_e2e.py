"""Paper Fig. 6 / section IV-C: end-to-end self-similar Burgers PINN training
time ratio, autodiff vs n-TangentProp, on the first profile (k=1, 3rd-order
smoothness -> 4 network derivatives per loss eval).

Full paper schedule is 15k Adam + 30k L-BFGS epochs; the benchmark runs a
scaled-down schedule with identical per-epoch work so the *ratio* (the
reported quantity) is preserved."""

from __future__ import annotations

import time

import jax

from repro.pinn import PINNRunConfig, train

from .common import csv_row


def run(k: int = 1, adam_steps: int = 60, lbfgs_steps: int = 15):
    rows = []
    times = {}
    for engine in ("ntp", "autodiff"):
        cfg = PINNRunConfig(k=k, engine=engine, adam_steps=adam_steps,
                            lbfgs_steps=lbfgs_steps, n_domain=256, n_origin=64,
                            log_every=adam_steps)
        t0 = time.perf_counter()
        res = train(cfg)
        total = time.perf_counter() - t0
        times[engine] = (res.adam_time_s, res.lbfgs_time_s, total, res.lam)
        rows.append(csv_row(f"burgers_k{k}_{engine}_adam", res.adam_time_s / adam_steps,
                            f"lam={res.lam:.4f}"))
        rows.append(csv_row(f"burgers_k{k}_{engine}_lbfgs",
                            res.lbfgs_time_s / max(lbfgs_steps, 1), ""))
    ratio_adam = times["autodiff"][0] / times["ntp"][0]
    ratio_lbfgs = times["autodiff"][1] / times["ntp"][1]
    ratio_total = times["autodiff"][2] / times["ntp"][2]
    rows.append(csv_row(f"burgers_k{k}_speedup", times["ntp"][2],
                        f"adam_x={ratio_adam:.2f};lbfgs_x={ratio_lbfgs:.2f};"
                        f"total_x={ratio_total:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
