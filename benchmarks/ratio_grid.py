"""Paper Fig. 4-5: autodiff/n-TangentProp runtime ratio across width, depth,
batch size, and derivative order (ratio > 1 means n-TangentProp is faster)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines, init_mlp, ntp_derivatives

from .common import csv_row, time_fn

WIDTHS = (24, 64)
DEPTHS = (3, 5)
BATCHES = (64, 256)
ORDERS = (2, 4, 6)


def run(trials: int = 3):
    rows = []
    for w in WIDTHS:
        for d in DEPTHS:
            key = jax.random.PRNGKey(w * d)
            params = init_mlp(key, 1, w, d, 1, dtype=jnp.float32)
            for b in BATCHES:
                x = jax.random.uniform(jax.random.PRNGKey(b), (b, 1),
                                       jnp.float32, -1, 1)
                for n in ORDERS:
                    t_ntp = time_fn(jax.jit(
                        lambda p, x, n=n: ntp_derivatives(p, x, n)),
                        params, x, trials=trials)
                    t_ad = time_fn(jax.jit(
                        lambda p, x, n=n: baselines.nested_jacfwd(p, x, n)),
                        params, x, trials=trials)
                    rows.append(csv_row(
                        f"ratio_w{w}_d{d}_b{b}_n{n}", t_ntp,
                        f"ratio={t_ad / t_ntp:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
