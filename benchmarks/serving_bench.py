"""Serving latency/throughput benchmark: p50/p99 vs offered request rate.

For every registered engine spec a :class:`repro.serving.DerivativeServer`
is stood up over a trained-shape dense network and an open-loop client
offers ``grid(x, order)`` requests at a fixed rate (requests/second); the
row records the p50 end-to-end latency (``us_per_call``) with p99,
achieved throughput, and overload count in the derived field.  Sweeping the
rate axis exposes the knee where queue wait dominates compute -- the number
a "millions of users" deployment sizes against -- and the per-spec rows
make the engines comparable at identical traffic.

Rows ride the standard ``name,us_per_call,derived`` CSV and the
``BENCH_*.json`` machinery; ``compare.py`` derives serving coverage
expectations from :data:`RATES` x :data:`SPECS` here, so dropping a rate or
an engine from the sweep fails the CI gate like a dropped operator.

Standalone (CI runs this per commit):

  PYTHONPATH=src python -m benchmarks.serving_bench --smoke \\
      --json BENCH_serving.json
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.runtime.metrics import percentile
from repro.serving import DerivativeServer, ServerOverloadedError, pick_bucket

from .common import csv_row
from .operators_bench import SPECS, spec_tag

# Offered request rates (requests/second).  Deliberately mode-independent:
# row NAMES must be stable across smoke/fast/full so the compare.py coverage
# gate (keyed on RATES x SPECS) and the checked-in baseline stay valid; the
# modes scale request COUNTS and shapes instead.
RATES = (25, 50, 100)

# per-mode kwargs, shared with benchmarks/run.py's suite registry
MODE_KWARGS = {
    "smoke": dict(n_requests=8, n_pts=8, width=8, depth=2, order=2),
    "fast": dict(n_requests=40, n_pts=32, width=16, depth=2, order=2),
    "full": dict(n_requests=200, n_pts=64, width=24, depth=3, order=4),
}


def row_name(spec: str, rate: int) -> str:
    return f"serve_grid_{spec_tag(spec)}_rate{rate}"


def _offer(server: DerivativeServer, queries, rate: float, n_requests: int,
           order: int):
    """Open-loop client: submit at the offered rate, then collect."""
    futures = []
    overloaded = 0
    t0 = time.monotonic()
    for i in range(n_requests):
        target = t0 + i / rate
        delay = target - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        try:
            futures.append(server.submit(queries[i % len(queries)],
                                         order=order))
        except ServerOverloadedError:
            overloaded += 1
    results = [f.result(timeout=120.0) for f in futures]
    elapsed = time.monotonic() - t0
    return results, elapsed, overloaded


def run(n_requests: int = 40, n_pts: int = 32, width: int = 16,
        depth: int = 2, order: int = 2, d_in: int = 2, rates=RATES,
        specs=SPECS):
    """One row per engine spec x offered rate: p50 latency (us_per_call),
    p99/throughput/overloads in derived."""
    from repro.core.network import make_network

    # NOTE: default dtype on purpose -- like operators_bench, this suite
    # never flips jax_enable_x64 (process-global; it would change every
    # suite after this one), so timing stays dtype-uniform across suites
    net = make_network("dense", d_in=d_in, d_out=1, width=width, depth=depth)
    params = net.init(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    # two request sizes, n_pts and n_pts//2 (same bucket in smoke, distinct
    # buckets in fast/full); coalescing can also merge them into larger
    # launches, so the server's bucket set is derived below to cover every
    # reachable launch shape and each bucket is warmed before the rate sweep
    n_half = max(n_pts // 2, 1)
    queries = [jax.random.uniform(k, (n, d_in))
               for k, n in zip(keys, (n_pts, n_half) * 2)]
    # capping the largest bucket at bucket(n_pts + n_half) bounds coalescing
    # to shapes the warm-up loop compiled -- a cold bucket on a measured row
    # would fold compile time into p99
    buckets = tuple(sorted({pick_bucket(m)
                            for m in (n_half, n_pts, n_pts + n_half)}))

    rows = []
    for spec in specs:
        with DerivativeServer(net, params, spec, buckets=buckets,
                              flush_window_s=0.002,
                              max_queue=max(4 * n_requests, 64)) as server:
            # warm every reachable bucket so rate rows measure dispatch,
            # never compile
            for b in buckets:
                server.grid(jnp.zeros((b, d_in)), order, timeout=300.0)
            for rate in rates:
                results, elapsed, overloaded = _offer(
                    server, queries, rate, n_requests, order)
                lat = [r.latency_s for r in results]
                p50, p99 = percentile(lat, 50), percentile(lat, 99)
                thr = len(results) / elapsed if elapsed > 0 else 0.0
                pad = (sum(r.pad_fraction for r in results)
                       / max(len(results), 1))
                derived = (f"p99_us={p99 * 1e6:.1f};"
                           f"throughput_rps={thr:.1f};offered_rps={rate};"
                           f"order={order};n={len(results)};"
                           f"overloaded={overloaded};"
                           f"pad_frac={pad:.2f}")
                rows.append(csv_row(row_name(spec, rate), p50, derived))
    return rows


def main() -> None:
    """Standalone driver mirroring run.py's --smoke/--full/--json contract
    for the serving suite only (CI invokes this per commit)."""
    import argparse
    import json
    import sys
    import traceback

    from .run import BENCH_SCHEMA_VERSION, parse_row

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    mode = "smoke" if args.smoke else ("full" if args.full else "fast")

    print("name,us_per_call,derived")
    records, failed = [], []
    try:
        for row in run(**MODE_KWARGS[mode]):
            print(row)
            sys.stdout.flush()
            records.append(parse_row("serving", mode, row))
    except Exception:
        traceback.print_exc()
        failed.append("serving")

    if args.json:
        payload = {"schema_version": BENCH_SCHEMA_VERSION, "mode": mode,
                   "only": "serving", "failed_suites": failed,
                   "results": records}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {len(records)} records to {args.json}",
              file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
