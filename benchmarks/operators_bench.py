"""Operator-axis benchmark: residual evaluation cost per PDE x engine.

For every registered differential operator this times one jitted residual
evaluation over a collocation batch, for the quasilinear n-TangentProp engine
(``ntp`` and ``ntp/pallas`` specs) and the nested-autodiff baseline.  The
per-operator ratio autodiff/ntp is the paper's headline quantity generalized
beyond the Burgers workload: it grows with the operator's derivative order
(heat/wave: 2, KdV: 3) exactly as the O(M^n) vs O(n p(n) M) analysis
predicts.  ``network`` selects any registered architecture (the engine
surface is network-agnostic), so e.g. ``network="fourier"`` times the
random-feature embedding at zero extra benchmark code.  Vector-valued
systems ride the same sweep: the network is built with ``d_out=op.d_out``,
so ``gray-scott`` times the shared-table two-component residual and
``navier-stokes`` the 4th-order polarization crosses.

``network_axis`` adds a second sweep -- each named architecture (residual
and the attention/transformer trunk by default, :data:`NETWORK_AXIS`) timed
on one representative operator under every engine spec, rows suffixed
``_net-*``.  The smoke run carries it, and ``compare.py`` derives coverage
expectations from the same tuples, so a trunk whose jet path rots fails CI
the way a dropped operator does.  The ``transformer x ntp/pallas`` rows
(smoke and full) exercise the FUSED attention path -- SelfAttention routes
its score Cauchy product + softmax through ``kernels.ops.
jet_attention_scores`` and RMSNorm through ``jet_rms_norm`` -- and carry a
``fused_attn=`` tag in their derived field.
"""

from __future__ import annotations

import functools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.core.engines import DerivativeEngine, EngineSpec, NTPEngine
from repro.core.network import make_network
from repro.data.collocation import sample_box
from repro.pinn.operators import get_operator, residual_values

from .common import axis_product, csv_row, time_fn

DEFAULT_OPS = ("burgers", "heat", "wave", "allen-cahn", "kdv", "poisson2d",
               "advection-diffusion", "navier-stokes", "gray-scott")

# the full engine sweep; compare.py derives its coverage expectations from
# this same tuple, so adding a spec here automatically widens the CI gate
SPECS = ("ntp", "ntp/pallas", "autodiff")

# the network axis: non-default architectures benchmarked (and coverage-
# gated, same mechanism as SPECS) on one representative operator per spec --
# the smoke run carries them so a trunk that stops jet-tracing fails the PR
NETWORK_AXIS = ("residual", "transformer")
NETWORK_AXIS_OP = "heat"

# the token-count scaling axis: the flash-jet attention kernel's reason to
# exist is that memory no longer grows with T^2, so the transformer trunk is
# timed at growing token counts (T = d_in coordinate tokens) under the
# fused pallas engine; rows are tagged ``flash=1`` and coverage-gated like
# every other axis
TOKEN_AXIS = (16, 64, 256)
TOKEN_AXIS_ORDER = 2

# the weak-scaling axis: the sharded jet engine (repro.parallel.jet_shard)
# timed at a FIXED per-device collocation batch while the host-device count
# grows, so the points/sec column reads as a weak-scaling curve.  Each
# device count needs its own interpreter (XLA_FLAGS must force the host
# platform device count before jax initializes), so every row is one
# subprocess -- which also keeps the benchmark process itself single-device
# like every other suite.  Rows are coverage-gated via compare.py like the
# operator and token axes.
DEVICE_AXIS = (1, 2, 4, 8)
WEAK_SCALE_OP = "heat"
WEAK_SCALE_SPEC = "ntp"


def spec_tag(spec: str) -> str:
    """CANONICAL engine spec -> the row-name tag used in benchmark output.
    Going through :class:`EngineSpec` keeps equivalent spellings ("ntp" vs
    "ntp/jnp") on one baseline row."""
    return str(EngineSpec.parse(spec)).replace("/", "_")


def row_name(op_name: str, spec: str, network: str = "dense") -> str:
    """Benchmark row name; non-default networks get a ``_net-`` suffix so
    the historical dense row names stay stable."""
    base = f"residual_{op_name}_{spec_tag(spec)}"
    return base if network == "dense" else f"{base}_net-{network}"


def _time_case(op, spec: str, network: str, n_pts: int, width: int,
               depth: int, trials: int) -> tuple:
    net = make_network(network, d_in=op.d_in, d_out=op.d_out, width=width,
                       depth=depth)
    engine = DerivativeEngine.from_spec(spec)
    params = net.init(jax.random.PRNGKey(0), dtype=jnp.float64)
    x = sample_box(jax.random.PRNGKey(1), op.domain, n_pts, jnp.float64)

    fn = jax.jit(functools.partial(
        lambda p, pts, _op, _eng, _net: residual_values(
            p, _op, pts, engine=_eng, net=_net),
        _op=op, _eng=engine, _net=net))
    t = time_fn(fn, params, x, trials=trials)
    derived = f"order={op.order};d_in={op.d_in};d_out={op.d_out};" \
              f"net={network}"
    if network == "transformer" and spec.endswith("pallas"):
        # records whether the fused flash-attention/rms_norm kernels were
        # REGISTERED for this run (capability registry at timing time).
        # Registry membership => actual module dispatch is enforced
        # separately by tests/test_parity.py's kernel-invocation guard, so
        # together the tag certifies the row timed the fused path.
        from repro.kernels import ops as kops
        fused = int("flash_attention" in kops.epilogues()
                    and "rms_norm" in kops.epilogues())
        derived += f";fused_attn={fused}"
    return t, derived


def token_row_name(tokens: int) -> str:
    return f"tokens_T{tokens}_transformer_{spec_tag('ntp/pallas')}"


def _time_token_case(tokens: int, width: int, trials: int) -> tuple:
    """One flash-path derivative pass on a transformer whose token count is
    ``tokens`` (coordinate tokens == d_in), timed via the engine surface the
    serving layer uses.  Depth 1 and a small batch keep the smoke run fast;
    the axis varies ONLY T, so the rows read as a scaling curve."""
    net = make_network("transformer", d_in=tokens, d_out=1, width=width,
                       depth=1)
    engine = NTPEngine("pallas")
    params = net.init(jax.random.PRNGKey(0), dtype=jnp.float64)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, tokens), jnp.float64,
                           -1.0, 1.0)
    fn = jax.jit(lambda p, pts: engine.derivs(net, p, pts, TOKEN_AXIS_ORDER))
    t = time_fn(fn, params, x, trials=trials)
    from repro.kernels import ops as kops
    flash = int("flash_attention" in kops.epilogues())
    return t, f"tokens={tokens};order={TOKEN_AXIS_ORDER};flash={flash}"


def weak_row_name(devices: int) -> str:
    return (f"weakscale_D{devices}_{WEAK_SCALE_OP}_"
            f"{spec_tag(WEAK_SCALE_SPEC)}")


def _time_weak_case(devices: int, pts_per_device: int, width: int,
                    depth: int, trials: int, timeout: int = 300) -> tuple:
    """One weak-scaling point: a subprocess with ``devices`` forced host
    devices times the sharded residual grid on ``devices * pts_per_device``
    collocation points (constant work per device).  Returns
    (median seconds/call, derived tag with the points/sec column)."""
    n_pts = devices * pts_per_device
    code = textwrap.dedent(f"""
        import json, time
        import jax, jax.numpy as jnp
        from repro.core.engines import DerivativeEngine
        from repro.core.network import make_network
        from repro.data.collocation import sample_box
        from repro.parallel.jet_shard import ShardedEngine, resolve_mesh
        from repro.pinn.operators import get_operator

        op = get_operator({WEAK_SCALE_OP!r})
        net = make_network("dense", d_in=op.d_in, d_out=op.d_out,
                           width={width}, depth={depth})
        eng = DerivativeEngine.from_spec({WEAK_SCALE_SPEC!r})
        mesh = resolve_mesh(data_parallel={devices})
        if mesh is not None:
            eng = ShardedEngine(eng, mesh)
        params = net.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        x = sample_box(jax.random.PRNGKey(1), op.domain, {n_pts}, jnp.float32)
        fn = jax.jit(lambda p, xs: eng.grid(net, p, xs, op.order))
        for _ in range(2):
            jax.block_until_ready(fn(params, x))
        times = []
        for _ in range({trials}):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(params, x))
            times.append(time.perf_counter() - t0)
        times.sort()
        print(json.dumps({{"s_per_call": times[len(times) // 2]}}))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count"
                        f"={devices}").strip()
    src = os.path.normpath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"weak-scaling child (devices={devices}) failed:\n"
                           f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
    t = json.loads(out.stdout.strip().splitlines()[-1])["s_per_call"]
    derived = (f"devices={devices};points={n_pts};"
               f"points_per_s={n_pts / t:.1f}")
    return t, derived


def run(n_pts: int = 256, width: int = 24, depth: int = 3, trials: int = 3,
        operators=DEFAULT_OPS, include_pallas: bool = True,
        network: str = "dense", network_axis=(), token_axis=TOKEN_AXIS,
        device_axis=DEVICE_AXIS):
    """Main sweep: every operator x engine spec on ``network``.  When
    ``network_axis`` names extra architectures, each is additionally timed
    on :data:`NETWORK_AXIS_OP` under every spec (rows suffixed ``_net-*``).
    ``token_axis`` adds the flash-attention token-count scaling rows
    (pallas-only, so it rides ``include_pallas`` like the pallas specs).
    ``device_axis`` adds the weak-scaling rows: the sharded jet engine at
    ``n_pts`` collocation points *per device* for each host-device count
    (one subprocess per count -- see :func:`_time_weak_case`)."""
    # NOTE: deliberately no jax_enable_x64 flip here -- it is process-global
    # and would change the precision (and timings) of every suite after this
    # one.  Timing is dtype-uniform with the other suites instead.
    specs = SPECS if include_pallas \
        else tuple(s for s in SPECS if not s.endswith("pallas"))
    rows = []
    ntp_times = {}
    for case in axis_product(op=operators, spec=specs):
        op = get_operator(case["op"])
        spec = case["spec"]
        t, derived = _time_case(op, spec, network, n_pts, width, depth, trials)
        if spec == "ntp":
            ntp_times[op.name] = t
        if spec == "autodiff" and op.name in ntp_times:
            derived += f";vs_ntp_x={t / ntp_times[op.name]:.2f}"
        rows.append(csv_row(row_name(op.name, spec, network), t, derived))

    axis_op = get_operator(NETWORK_AXIS_OP)
    for case in axis_product(net=tuple(network_axis), spec=specs):
        t, derived = _time_case(axis_op, case["spec"], case["net"], n_pts,
                                width, depth, trials)
        rows.append(csv_row(row_name(axis_op.name, case["spec"], case["net"]),
                            t, derived))

    if include_pallas:
        for tokens in token_axis:
            t, derived = _time_token_case(tokens, width=8, trials=trials)
            rows.append(csv_row(token_row_name(tokens), t, derived))

    for devices in device_axis:
        t, derived = _time_weak_case(devices, pts_per_device=n_pts,
                                     width=width, depth=depth, trials=trials)
        rows.append(csv_row(weak_row_name(devices), t, derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
