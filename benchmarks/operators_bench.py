"""Operator-axis benchmark: residual evaluation cost per PDE x engine.

For every registered differential operator this times one jitted residual
evaluation over a collocation batch, for the quasilinear n-TangentProp engine
(jnp and pallas impls) and the nested-autodiff baseline.  The per-operator
ratio autodiff/ntp is the paper's headline quantity generalized beyond the
Burgers workload: it grows with the operator's derivative order (heat/wave:
2, KdV: 3) exactly as the O(M^n) vs O(n p(n) M) analysis predicts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ntp import init_mlp
from repro.data.collocation import sample_box
from repro.pinn.operators import get_operator, operator_names, residual_values

from .common import axis_product, csv_row, time_fn

DEFAULT_OPS = ("burgers", "heat", "wave", "allen-cahn", "kdv", "poisson2d")


def run(n_pts: int = 256, width: int = 24, depth: int = 3, trials: int = 3,
        operators=DEFAULT_OPS, include_pallas: bool = True):
    # NOTE: deliberately no jax_enable_x64 flip here -- it is process-global
    # and would change the precision (and timings) of every suite after this
    # one.  Timing is dtype-uniform with the other suites instead.
    rows = []
    ntp_times = {}
    cases = list(axis_product(op=operators, engine=("ntp", "autodiff")))
    for case in cases:
        op = get_operator(case["op"])
        params = init_mlp(jax.random.PRNGKey(0), op.d_in, width, depth, 1,
                          dtype=jnp.float64)
        x = sample_box(jax.random.PRNGKey(1), op.domain, n_pts, jnp.float64)

        impls = ("jnp", "pallas") if (case["engine"] == "ntp" and
                                      include_pallas) else ("jnp",)
        for impl in impls:
            fn = jax.jit(functools.partial(
                lambda p, pts, _op, _engine, _impl: residual_values(
                    p, _op, pts, engine=_engine, impl=_impl),
                _op=op, _engine=case["engine"], _impl=impl))
            t = time_fn(fn, params, x, trials=trials)
            tag = case["engine"] if impl == "jnp" else f"ntp_{impl}"
            if case["engine"] == "ntp" and impl == "jnp":
                ntp_times[op.name] = t
            derived = f"order={op.order};d_in={op.d_in}"
            if case["engine"] == "autodiff" and op.name in ntp_times:
                derived += f";vs_ntp_x={t / ntp_times[op.name]:.2f}"
            rows.append(csv_row(f"residual_{op.name}_{tag}", t, derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
