"""Benchmark regression gate: diff a fresh ``--json`` run against the
checked-in baseline.

Two classes of check, with different strictness (CI runners have noisy
timings, but coverage is exact):

* **coverage (hard failure)** -- every (suite, name) pair present in the
  baseline must appear in the current run, every operator in the registry
  must appear under every benchmarked engine spec, and every serving rate x
  engine row must appear (the COVERAGE registry maps suite -> expected-row
  derivation).  A new operator or suite that silently drops out of the
  benchmark matrix fails the PR; a newly *added* row does not (it will
  enter the baseline when ``baseline_smoke.json`` is regenerated).
  Coverage is **suite-scoped**: a ``--only SUITE`` run (the per-suite CI
  jobs) answers only for its own suite's baseline/registry rows.
* **timing (warn-only by default)** -- rows slower than ``--max-ratio``
  times their baseline are reported; pass ``--strict-timing`` to turn those
  warnings into failures (meant for dedicated perf hardware, not shared CPU
  CI runners).

Regenerate the baseline after intentionally changing the benchmark matrix
(``--update-baseline`` refuses a run with failed suites or coverage holes,
so an incomplete matrix can never become the new reference):

  PYTHONPATH=src python -m benchmarks.run --only operators --smoke \\
      --json BENCH_operators.json
  PYTHONPATH=src python -m benchmarks.compare --current BENCH_operators.json \\
      --update-baseline
"""

from __future__ import annotations

import argparse
import json
import sys

DEFAULT_BASELINE = "benchmarks/baseline_smoke.json"


def load(path: str) -> dict:
    with open(path) as fh:
        payload = json.load(fh)
    for field in ("schema_version", "results"):
        if field not in payload:
            raise SystemExit(f"{path}: not a benchmark JSON (missing "
                             f"{field!r}); regenerate with run.py --json")
    return payload


def index(payload: dict) -> dict:
    return {(r["suite"], r["name"]): r for r in payload["results"]}


def expected_operator_rows() -> set:
    """Every registered operator under every engine spec the operators suite
    benchmarks, plus every network-axis architecture on the representative
    operator -- all imported from their owning modules, so registering a new
    PDE, adding an engine spec, or adding a trunk to the network axis
    without benchmark coverage fails the gate."""
    from repro.pinn.operators import operator_names

    from .operators_bench import (DEVICE_AXIS, NETWORK_AXIS, NETWORK_AXIS_OP,
                                  SPECS, TOKEN_AXIS, row_name, token_row_name,
                                  weak_row_name)
    rows = {("operators", row_name(op, spec))
            for op in operator_names() for spec in SPECS}
    rows |= {("operators", row_name(NETWORK_AXIS_OP, spec, net))
             for net in NETWORK_AXIS for spec in SPECS}
    rows |= {("operators", token_row_name(t)) for t in TOKEN_AXIS}
    # the weak-scaling axis: dropping a device count from the sharded-jet
    # sweep fails CI the way a dropped operator does
    rows |= {("operators", weak_row_name(d)) for d in DEVICE_AXIS}
    return rows


def expected_serving_rows() -> set:
    """Every engine spec at every offered request rate -- derived from the
    serving benchmark's own axes, so narrowing the rate sweep or dropping a
    spec from the serving matrix fails the gate like a dropped operator."""
    from .operators_bench import SPECS
    from .serving_bench import RATES, row_name
    return {("serving", row_name(spec, rate))
            for spec in SPECS for rate in RATES}


# suite name -> expected-coverage derivation; a suite absent here is gated
# only on its baseline rows, not on a registry
COVERAGE = {"operators": expected_operator_rows,
            "serving": expected_serving_rows}


def run_scope(cur: dict, base: dict = None) -> set:
    """The suites a run is accountable for.  A ``--only SUITE`` run answers
    for that suite alone (so the per-suite CI jobs don't fail on each
    other's baseline rows); a full run answers for every suite in the
    baseline, the current results, and the coverage registry."""
    if cur.get("only"):
        return {cur["only"]}
    suites = {r["suite"] for r in cur["results"]} | set(COVERAGE)
    if base is not None:
        suites |= {r["suite"] for r in base["results"]}
    return suites


def expected_rows(scope: set) -> set:
    rows = set()
    for suite in scope & set(COVERAGE):
        rows |= COVERAGE[suite]()
    return rows


def update_baseline(args, cur: dict) -> None:
    """Promote a fresh, complete ``--json`` run to the checked-in baseline.

    A ``--only SUITE`` run is merged: its suite's rows replace that suite in
    the existing baseline and every other suite's rows are kept, so the
    operators and serving baselines can be regenerated independently."""
    if cur.get("failed_suites"):
        raise SystemExit(f"refusing to update baseline: suites raised during "
                         f"the run: {sorted(cur['failed_suites'])}")
    try:
        old = load(args.baseline)
    except (OSError, SystemExit):
        old = None                       # no existing baseline to match
    if old is not None and cur.get("mode") != old.get("mode"):
        raise SystemExit(
            f"refusing to update baseline: existing {args.baseline} is a "
            f"{old.get('mode')!r} run but --current is {cur.get('mode')!r}; "
            f"shapes (and therefore timings) are not comparable -- rerun "
            f"with matching flags or point --baseline at a new file")
    scope = run_scope(cur)
    missing = sorted(expected_rows(scope) - set(index(cur)))
    if missing:
        raise SystemExit("refusing to update baseline: registered rows "
                         "missing from the run:\n  " +
                         "\n  ".join(f"{s}/{n}" for s, n in missing))
    merged = dict(cur)
    kept = ([r for r in old["results"] if r["suite"] not in scope]
            if (old is not None and cur.get("only")) else [])
    merged["results"] = sorted(kept + cur["results"],
                               key=lambda r: (r["suite"], r["name"]))
    if kept:
        merged["only"] = None            # the baseline is now multi-suite
    with open(args.baseline, "w") as fh:
        json.dump(merged, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"baseline updated: {args.baseline} <- {args.current} "
          f"({len(cur['results'])} new rows, {len(kept)} kept, "
          f"mode={cur.get('mode')!r})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--current", required=True,
                    help="fresh run.py --json output (e.g. "
                         "BENCH_operators.json)")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="flag rows slower than RATIO x baseline "
                         "(default 2.0)")
    ap.add_argument("--strict-timing", action="store_true",
                    help="timing regressions fail instead of warn (for "
                         "dedicated perf hardware)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write --current over --baseline (after checking "
                         "the run is complete) instead of diffing")
    args = ap.parse_args()

    if args.update_baseline:
        update_baseline(args, load(args.current))
        return

    base, cur = load(args.baseline), load(args.current)
    if cur["schema_version"] != base["schema_version"]:
        raise SystemExit(f"schema mismatch: baseline v{base['schema_version']}"
                         f" vs current v{cur['schema_version']}")
    if cur.get("mode") != base.get("mode"):
        raise SystemExit(
            f"mode mismatch: baseline is a {base.get('mode')!r} run, current "
            f"is {cur.get('mode')!r}; coverage and timings are only "
            f"comparable at matching shapes (rerun with matching flags or "
            f"regenerate the baseline)")
    bidx, cidx = index(base), index(cur)
    scope = run_scope(cur, base)
    failures, warnings = [], []

    if cur.get("failed_suites"):
        failures.append(f"suites raised during the run: "
                        f"{sorted(cur['failed_suites'])}")

    missing = sorted({k for k in bidx if k[0] in scope} - set(cidx))
    if missing:
        failures.append("rows present in the baseline but missing from the "
                        "current run:\n  " +
                        "\n  ".join(f"{s}/{n}" for s, n in missing))

    missing_reg = sorted(expected_rows(scope) - set(cidx))
    if missing_reg:
        failures.append("registered rows without benchmark coverage:\n"
                        "  " + "\n  ".join(f"{s}/{n}" for s, n in missing_reg))

    for key in sorted(set(bidx) & set(cidx)):
        b, c = bidx[key]["us_per_call"], cidx[key]["us_per_call"]
        if b > 0 and c > args.max_ratio * b:
            warnings.append(f"{key[0]}/{key[1]}: {c:.1f}us vs baseline "
                            f"{b:.1f}us ({c / b:.2f}x)")

    if warnings:
        kind = "FAIL" if args.strict_timing else "WARN"
        print(f"[{kind}] {len(warnings)} row(s) slower than "
              f"{args.max_ratio:.1f}x baseline:")
        for w in warnings:
            print(f"  {w}")
        if args.strict_timing:
            failures.append("timing regressions (--strict-timing)")

    n_rows = len(cidx)
    if failures:
        print(f"benchmark gate FAILED ({n_rows} current rows):")
        for f in failures:
            print(f"- {f}")
        sys.exit(1)
    print(f"benchmark gate OK: {n_rows} rows, coverage complete"
          + (f", {len(warnings)} timing warning(s)" if warnings else ""))


if __name__ == "__main__":
    main()
