"""Inject the roofline tables (baseline + optimized) into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.make_experiments
"""

from __future__ import annotations

import json
import os
import re

from .roofline import RESULTS, load

BASELINE = os.path.join(os.path.dirname(__file__), "results", "dryrun_baseline")
EXP = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "EXPERIMENTS.md") if False else os.path.join(
    os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def load_dir(path, mesh="single"):
    out = []
    for name in sorted(os.listdir(path)):
        if name.endswith(f"__{mesh}.json"):
            with open(os.path.join(path, name)) as f:
                out.append(json.load(f))
    return out


def table(records, title):
    rows = [f"**{title}**", "",
            "| arch | shape | compute s | memory s | collective s | bottleneck "
            "| mem/dev GiB | useful |",
            "|---|---|---|---|---|---|---|---|"]
    for rec in records:
        if rec.get("skipped"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skip (pure full-attn) | — | — |")
            continue
        if rec.get("error"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | |")
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['compute_s']:.4f} | "
            f"{rec['memory_s']:.4f} | {rec['collective_s']:.4f} | "
            f"{rec['bottleneck']} | {rec['per_device_mem_gb']:.2f} | "
            f"{rec['useful_fraction']:.2f} |")
    return "\n".join(rows)


def main():
    base = load_dir(BASELINE)
    opt = load_dir(RESULTS)
    block = (table(base, "Baseline (paper-faithful + straightforward sharding; "
                         "frozen pre-hillclimb)") + "\n\n" +
             table(opt, "Optimized (global code fixes; per-cell flags listed "
                        "in section 4.4)"))
    with open(EXP) as f:
        text = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        text = text.replace(marker, marker + "\n\n" + block, 1)
    else:  # refresh: replace between marker and the next section header
        text = re.sub(r"(<!-- ROOFLINE_TABLE -->).*?(\n\nReading of the table)",
                      r"\1\n\n" + block.replace("\\", "\\\\") + r"\2",
                      text, flags=re.S)
    with open(EXP, "w") as f:
        f.write(text)
    print(f"wrote {len(base)} baseline + {len(opt)} optimized rows")


if __name__ == "__main__":
    main()
