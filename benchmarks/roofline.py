"""Aggregate the dry-run JSONs into the section-Roofline table.

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
emits (a) CSV rows for benchmarks/run.py and (b) the markdown table used in
EXPERIMENTS.md section Roofline."""

from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

MOVE_HINTS = {
    "compute": "raise MXU utilization: bigger per-chip tiles (less TP for "
               "small models), drop masked-causal waste, fuse jets into GEMMs",
    "memory": "cut HBM traffic: larger fusion regions, fewer remat passes, "
              "bf16 intermediates, flash-style recompute already applied",
    "collective": "cut bytes on ICI: less TP for small models, overlap "
                  "collectives with compute, int8 gradient compression",
}


def load(mesh: str = "single") -> List[Dict]:
    out = []
    if not os.path.isdir(RESULTS):
        return out
    for name in sorted(os.listdir(RESULTS)):
        if not name.endswith(f"__{mesh}.json"):
            continue
        with open(os.path.join(RESULTS, name)) as f:
            out.append(json.load(f))
    return out


def markdown_table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | c (s) | m (s) | x (s) | bottleneck | mem/dev GiB | "
        "HLO TF | model TF | useful |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh):
        if rec.get("skipped"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped ({rec['skipped'][:30]}…) | — | — | — | — |")
            continue
        if rec.get("error"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"ERROR | — | — | — | — |")
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['compute_s']:.4f} | "
            f"{rec['memory_s']:.4f} | {rec['collective_s']:.4f} | "
            f"{rec['bottleneck']} | {rec['per_device_mem_gb']:.2f} | "
            f"{rec['hlo_gflops'] / 1e3:.2f} | {rec['model_gflops'] / 1e3:.2f} | "
            f"{rec['useful_fraction']:.2f} |")
    return "\n".join(rows)


def run():
    out = []
    for rec in load("single"):
        if rec.get("skipped") or rec.get("error"):
            continue
        dom = rec["bottleneck"]
        out.append(f"roofline_{rec['arch']}_{rec['shape']},"
                   f"{max(rec['compute_s'], rec['memory_s'], rec['collective_s']) * 1e6:.0f},"
                   f"bottleneck={dom}")
    return out


if __name__ == "__main__":
    print(markdown_table("single"))
