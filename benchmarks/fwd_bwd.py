"""Paper Fig. 1-3: forward / forward+backward times vs derivative order,
autodiff (nested grad) vs n-TangentProp, for the paper's 3x24 tanh PINN net.

Expectation being reproduced: autodiff wall time grows exponentially in n;
n-TangentProp grows quasilinearly (~ n * p(n)); the crossover sits at small n.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines, init_mlp, ntp_derivatives
from repro.core.partitions import partition_count

from .common import csv_row, time_fn


def run(max_order: int = 6, batch: int = 256, width: int = 24, depth: int = 3,
        trials: int = 5, fwd_only: bool = False):
    key = jax.random.PRNGKey(0)
    params = init_mlp(key, 1, width, depth, 1, dtype=jnp.float32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (batch, 1), jnp.float32, -1, 1)
    rows = []
    for n in range(1, max_order + 1):
        ntp_f = jax.jit(lambda p, x, n=n: ntp_derivatives(p, x, n))
        ad_f = jax.jit(lambda p, x, n=n: baselines.nested_jacfwd(p, x, n))
        t_ntp = time_fn(ntp_f, params, x, trials=trials)
        t_ad = time_fn(ad_f, params, x, trials=trials)
        rows.append(csv_row(f"fwd_ntp_n{n}", t_ntp, f"pn={partition_count(n)}"))
        rows.append(csv_row(f"fwd_autodiff_n{n}", t_ad,
                            f"ratio={t_ad / t_ntp:.2f}"))
        if not fwd_only:
            loss_ntp = jax.jit(jax.grad(
                lambda p, x, n=n: jnp.sum(ntp_derivatives(p, x, n)[n] ** 2)))
            loss_ad = jax.jit(jax.grad(
                lambda p, x, n=n: jnp.sum(baselines.nested_jacfwd(p, x, n)[n] ** 2)))
            t_ntp_b = time_fn(loss_ntp, params, x, trials=trials)
            t_ad_b = time_fn(loss_ad, params, x, trials=trials)
            rows.append(csv_row(f"fwdbwd_ntp_n{n}", t_ntp_b, ""))
            rows.append(csv_row(f"fwdbwd_autodiff_n{n}", t_ad_b,
                                f"ratio={t_ad_b / t_ntp_b:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
