"""Paper section III-B bound: p(n) = O(e^sqrt(n)/n) and the total contraction
work sum_k p(k) stays quasilinear -- the constant behind O(e^sqrt(n) M)."""

from __future__ import annotations

import math

from repro.core import partition_count, total_fdb_terms

from .common import csv_row


def run(max_order: int = 16):
    rows = []
    for n in range(1, max_order + 1):
        pn = partition_count(n)
        bound = math.exp(math.pi * math.sqrt(2 * n / 3)) / (4 * n * math.sqrt(3))
        rows.append(csv_row(f"partition_n{n}", 0.0,
                            f"p={pn};hardy_ramanujan={bound:.1f};"
                            f"cum_terms={total_fdb_terms(n)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
