"""Shared benchmark utilities: stable timing on CPU (paper section IV-B
methodology adapted: jit warm-up = their cudnn.benchmark, block_until_ready =
their CUDA sync, explicit gc between trials, perf_counter)."""

from __future__ import annotations

import gc
import itertools
import time
from typing import Callable, Dict, Iterator

import jax


def time_fn(fn: Callable, *args, trials: int = 5, warmup: int = 2) -> float:
    """Median seconds per call of a jitted function."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    gc.collect()
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def axis_product(**axes) -> Iterator[Dict]:
    """Cartesian product over named benchmark axes, yielding kwargs dicts.

    The operator benchmarks sweep ``axis_product(op=..., engine=...)``; any
    suite that grows a new dimension (impl, order, batch) just adds a kwarg.
    """
    names = list(axes)
    for combo in itertools.product(*(axes[n] for n in names)):
        yield dict(zip(names, combo))
