"""Optimizers: Adam/AdamW (sharded states) and strong-Wolfe L-BFGS."""

from .adam import AdamState, adam_abstract, adam_init, adam_update
from .lbfgs import LBFGSResult, lbfgs
