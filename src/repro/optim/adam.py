"""Adam / AdamW on arbitrary pytrees, with shardable state.

Optimizer state mirrors the parameter pytree leaf-for-leaf, so the launcher
shards it with the *same* PartitionSpecs as the parameters (FSDP included) --
no special casing.  ``state_dtype`` lets very large models (llama4-maverick)
keep moments in bf16; the update math always runs in fp32.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: Any             # pytree like params
    v: Any             # pytree like params


def adam_init(params, state_dtype: Optional[str] = None) -> AdamState:
    def zeros(p):
        dt = jnp.dtype(state_dtype) if state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)

    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree_util.tree_map(zeros, params),
                     v=jax.tree_util.tree_map(zeros, params))


def adam_abstract(params, state_dtype: Optional[str] = None) -> AdamState:
    """ShapeDtypeStruct twin of adam_init for the dry-run."""
    def spec(p):
        dt = jnp.dtype(state_dtype) if state_dtype else p.dtype
        return jax.ShapeDtypeStruct(p.shape, dt)

    return AdamState(step=jax.ShapeDtypeStruct((), jnp.int32),
                     m=jax.tree_util.tree_map(spec, params),
                     v=jax.tree_util.tree_map(spec, params))


def adam_update(grads, state: AdamState, params, lr, *, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0,
                grad_clip: Optional[float] = None):
    """Returns (new_params, new_state).  lr may be a scalar or traced value."""
    step = state.step + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
        grads = jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def moments(g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        return m32, v32

    def new_param(p, g, m, v):
        m32, v32 = moments(g, m, v)
        u = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    tm = jax.tree_util.tree_map
    new_p = tm(new_param, params, grads, state.m, state.v)
    new_m = tm(lambda g, m, v: moments(g, m, v)[0].astype(m.dtype),
               grads, state.m, state.v)
    new_v = tm(lambda g, m, v: moments(g, m, v)[1].astype(v.dtype),
               grads, state.m, state.v)
    return new_p, AdamState(step, new_m, new_v)
