"""L-BFGS with a strong-Wolfe line search, pure JAX.

The paper's high-accuracy PINN phase is L-BFGS-dominated and line-search
forward passes are exactly where n-TangentProp wins (paper section IV-C), so
this is substrate, not garnish.  Implementation follows Nocedal & Wright
(Alg. 6.1 two-loop recursion; Alg. 3.5/3.6 bracket-zoom line search),
operating on the raveled parameter vector.  The driver loop is Python (PINN
scale: thousands of steps of a <10k-parameter network); the value/grad
closure is jitted by the caller.
"""

from __future__ import annotations

from typing import Callable, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class LBFGSResult(NamedTuple):
    params: any
    loss_history: list
    n_evals: int


def _two_loop(grad, s_list, y_list):
    q = grad
    alphas = []
    for s, y in zip(reversed(s_list), reversed(y_list)):
        rho = 1.0 / jnp.vdot(y, s)
        a = rho * jnp.vdot(s, q)
        q = q - a * y
        alphas.append((a, rho))
    if s_list:
        s, y = s_list[-1], y_list[-1]
        gamma = jnp.vdot(s, y) / jnp.vdot(y, y)
    else:
        gamma = 1.0
    r = gamma * q
    for (a, rho), s, y in zip(reversed(alphas), s_list, y_list):
        b = rho * jnp.vdot(y, r)
        r = r + (a - b) * s
    return r


def _wolfe_zoom(phi, lo, hi, f_lo, f0, g0, c1, c2, max_iter=12):
    """Bisection zoom satisfying strong Wolfe."""
    for _ in range(max_iter):
        t = 0.5 * (lo + hi)
        f_t, g_t = phi(t)
        if (f_t > f0 + c1 * t * g0) or (f_t >= f_lo):
            hi = t
        else:
            if abs(g_t) <= -c2 * g0:
                return t, f_t
            if g_t * (hi - lo) >= 0:
                hi = lo
            lo, f_lo = t, f_t
    return t, f_t


def _wolfe_search(phi, f0, g0, c1=1e-4, c2=0.9, t_init=1.0, max_iter=10):
    """Strong-Wolfe line search; phi(t) -> (f, dphi/dt)."""
    t_prev, f_prev = 0.0, f0
    t = t_init
    for i in range(max_iter):
        f_t, g_t = phi(t)
        if (f_t > f0 + c1 * t * g0) or (i > 0 and f_t >= f_prev):
            return _wolfe_zoom(phi, t_prev, t, f_prev, f0, g0, c1, c2)
        if abs(g_t) <= -c2 * g0:
            return t, f_t
        if g_t >= 0:
            return _wolfe_zoom(phi, t, t_prev, f_t, f0, g0, c1, c2)
        t_prev, f_prev = t, f_t
        t = 2.0 * t
    return t, f_t


def lbfgs(value_and_grad: Callable, params, *, steps: int, history: int = 10,
          tol: float = 1e-12, callback: Callable | None = None) -> LBFGSResult:
    """Minimize.  ``value_and_grad(params) -> (loss, grads)`` (jitted by caller)."""
    x, unravel = ravel_pytree(params)

    n_evals = 0

    def vg(xv):
        nonlocal n_evals
        n_evals += 1
        f, g = value_and_grad(unravel(xv))
        return f, ravel_pytree(g)[0]

    f, g = vg(x)
    s_list: List = []
    y_list: List = []
    losses = [float(f)]

    for it in range(steps):
        d = -_two_loop(g, s_list, y_list)
        dg = jnp.vdot(g, d)
        if dg >= 0:  # not a descent direction; reset memory
            s_list, y_list = [], []
            d, dg = -g, -jnp.vdot(g, g)

        def phi(t):
            ft, gt = vg(x + t * d)
            return ft, jnp.vdot(gt, d)

        t, f_new = _wolfe_search(phi, f, dg, t_init=1.0 if s_list else
                                 min(1.0, 1.0 / (jnp.abs(dg) + 1e-12)))
        x_new = x + t * d
        _, g_new = vg(x_new)

        s, y = x_new - x, g_new - g
        if jnp.vdot(s, y) > 1e-10 * jnp.vdot(y, y):
            s_list.append(s)
            y_list.append(y)
            if len(s_list) > history:
                s_list.pop(0)
                y_list.pop(0)

        x, f, g = x_new, f_new, g_new
        losses.append(float(f))
        if callback is not None:
            callback(it, float(f), unravel(x))
        if len(losses) > 2 and abs(losses[-2] - losses[-1]) < tol * max(1.0, abs(losses[-2])):
            break

    return LBFGSResult(unravel(x), losses, n_evals)
