"""Collocation-point samplers for PINN training."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_grid(lo: float, hi: float, n: int, dtype=jnp.float64) -> jnp.ndarray:
    return jnp.linspace(lo, hi, n, dtype=dtype)[:, None]


def random_points(key: jax.Array, lo: float, hi: float, n: int,
                  dtype=jnp.float64) -> jnp.ndarray:
    return jax.random.uniform(key, (n, 1), dtype, lo, hi)


def origin_cluster(key: jax.Array, radius: float, n: int,
                   dtype=jnp.float64) -> jnp.ndarray:
    """Points concentrated near x=0 where the high-order smoothness loss acts."""
    return jax.random.uniform(key, (n, 1), dtype, -radius, radius)


def resample(key: jax.Array, lo: float, hi: float, n_domain: int,
             n_origin: int, origin_radius: float, dtype=jnp.float64):
    k1, k2 = jax.random.split(key)
    return (random_points(k1, lo, hi, n_domain, dtype),
            origin_cluster(k2, origin_radius, n_origin, dtype))


# ---------------------------------------------------------------------------
# d-dimensional boxes (the operator subsystem's collocation surface)
# ---------------------------------------------------------------------------

Domain = tuple  # ((lo, hi), ...) -- one interval per input axis


def sample_box(key: jax.Array, domain: Domain, n: int,
               dtype=jnp.float64) -> jnp.ndarray:
    """(n, d) uniform interior collocation points in a box domain."""
    d = len(domain)
    lo = jnp.asarray([b[0] for b in domain], dtype)
    hi = jnp.asarray([b[1] for b in domain], dtype)
    return lo + (hi - lo) * jax.random.uniform(key, (n, d), dtype)


def boundary_grid(domain: Domain, n_per_face: int,
                  dtype=jnp.float64) -> jnp.ndarray:
    """Deterministic points on every face of the box (both endpoints of each
    axis).  For time-dependent PDEs trained by manufactured solutions the
    t=0 face supplies the initial condition and the other faces Dirichlet
    data -- supervising on the t=T face too is harmless extra data."""
    d = len(domain)
    if d == 1:
        return jnp.asarray([[domain[0][0]], [domain[0][1]]], dtype)
    n_side = max(2, int(round(n_per_face ** (1.0 / (d - 1)))))
    faces = []
    for a in range(d):
        others = [i for i in range(d) if i != a]
        axes = [jnp.linspace(domain[i][0], domain[i][1], n_side, dtype=dtype)
                for i in others]
        mesh = jnp.meshgrid(*axes, indexing="ij")
        rest = jnp.stack([m.ravel() for m in mesh], axis=-1)
        for side in domain[a]:
            pts = jnp.zeros((rest.shape[0], d), dtype)
            pts = pts.at[:, jnp.asarray(others)].set(rest).at[:, a].set(side)
            faces.append(pts)
    return jnp.concatenate(faces)


def eval_grid(domain: Domain, n_per_axis: int, dtype=jnp.float64) -> jnp.ndarray:
    """Dense tensor-product grid over the box, for accuracy reporting."""
    axes = [jnp.linspace(lo, hi, n_per_axis, dtype=dtype) for lo, hi in domain]
    mesh = jnp.meshgrid(*axes, indexing="ij")
    return jnp.stack([m.ravel() for m in mesh], axis=-1)
