"""Collocation-point samplers for PINN training."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_grid(lo: float, hi: float, n: int, dtype=jnp.float64) -> jnp.ndarray:
    return jnp.linspace(lo, hi, n, dtype=dtype)[:, None]


def random_points(key: jax.Array, lo: float, hi: float, n: int,
                  dtype=jnp.float64) -> jnp.ndarray:
    return jax.random.uniform(key, (n, 1), dtype, lo, hi)


def origin_cluster(key: jax.Array, radius: float, n: int,
                   dtype=jnp.float64) -> jnp.ndarray:
    """Points concentrated near x=0 where the high-order smoothness loss acts."""
    return jax.random.uniform(key, (n, 1), dtype, -radius, radius)


def resample(key: jax.Array, lo: float, hi: float, n_domain: int,
             n_origin: int, origin_radius: float, dtype=jnp.float64):
    k1, k2 = jax.random.split(key)
    return (random_points(k1, lo, hi, n_domain, dtype),
            origin_cluster(k2, origin_radius, n_origin, dtype))
