"""Data pipelines: PINN collocation sampling + deterministic synthetic tokens."""

from . import collocation, tokens
