"""Deterministic synthetic token pipeline for LM training/serving drivers.

Streams sharded batches without any filesystem dependency: tokens are a
counter-based PRNG function of (step, position), so every host in a multi-pod
job can materialize exactly its own shard (no broadcast, no skew), restarts
are reproducible from the step counter alone, and the validation loss is a
stable quantity.  A markov-ish structure (mixing the previous token id into
the draw) gives the model something learnable beyond uniform noise.
"""

from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCfg


def synthetic_batch(cfg: ArchConfig, shape: ShapeCfg, step: int,
                    batch_slice: slice | None = None,
                    dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Materialize the global (or host-sliced) batch for ``step``."""
    b = shape.global_batch
    if batch_slice is not None:
        b = batch_slice.stop - batch_slice.start
        offset = batch_slice.start
    else:
        offset = 0
    s = shape.seq_len
    key = jax.random.fold_in(jax.random.PRNGKey(0x5EED), step)

    n_text = s - (cfg.vlm_image_tokens or 0)
    base = jax.random.randint(jax.random.fold_in(key, offset), (b, n_text),
                              0, cfg.vocab, jnp.int32)
    # markov-ish: token_t depends on token_{t-1} (learnable bigram structure)
    shifted = jnp.roll(base, 1, axis=1)
    toks = (base // 7 + shifted // 3) % cfg.vocab
    out: Dict[str, jnp.ndarray] = {"tokens": toks}
    if cfg.encoder is not None:
        out["frames"] = jax.random.normal(jax.random.fold_in(key, 1),
                                          (b, cfg.encoder.seq, cfg.d_model), dtype)
    if cfg.vlm_image_tokens:
        from repro.models.transformer import VLM_EMBED_DIM
        out["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.vlm_image_tokens, VLM_EMBED_DIM), dtype)
    return out


def batch_stream(cfg: ArchConfig, shape: ShapeCfg, start_step: int = 0
                 ) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, shape, step)
        step += 1
