"""Shape bucketing for the derivative server.

JAX compiles one executable per input shape, so a server that accepted raw
``(N, d_in)`` query sets would recompile for every distinct N a client sends.
Instead, point counts are rounded up to a small fixed set of **buckets**:
requests are padded with zero rows to the smallest admissible bucket, the
compiled-executable cache is keyed on the bucket (not the raw N), and pad
rows are sliced off before results are returned.  Every row of the jet
forward is batch-independent (dense layers act row-wise, the transformer's
token axis is per-point), so padding changes neither the values nor -- for
the ntp engines -- the bits of the live rows; tests/test_serving.py pins
both properties.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

# Powers of two keep the compiled-executable count logarithmic in the
# largest admissible request while capping pad waste at <50% per launch.
DEFAULT_BUCKETS: Tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512)


class RequestTooLargeError(ValueError):
    """A single request exceeds the largest configured bucket."""


def pick_bucket(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket admitting ``n`` rows; typed error when none does."""
    if n < 1:
        raise ValueError(f"need at least one query point, got n={n}")
    for b in sorted(buckets):
        if n <= b:
            return int(b)
    raise RequestTooLargeError(
        f"{n} query points exceed the largest bucket "
        f"({max(buckets)}); split the request or configure larger buckets")


def pad_to(x: jnp.ndarray, bucket: int, *, copy: bool = False) -> jnp.ndarray:
    """Zero-pad ``x`` (N, d_in) to (bucket, d_in).

    On an exact fit the input is returned unchanged unless ``copy=True``,
    which forces a fresh buffer the caller owns -- required when the launch
    donates its input (donating an array the client still holds would delete
    it out from under them).
    """
    n = x.shape[0]
    if n == bucket:
        return jnp.array(x, copy=True) if copy else x
    if n > bucket:
        raise ValueError(f"cannot pad {n} rows down to bucket {bucket}")
    pad = jnp.zeros((bucket - n,) + x.shape[1:], x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def pad_fraction(n: int, bucket: int) -> float:
    """Fraction of the launch that is padding (0.0 on an exact fit)."""
    return (bucket - n) / bucket
