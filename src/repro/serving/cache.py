"""Compiled-executable cache for the derivative server.

Each distinct ``(network id, engine spec, grid|cross, order/axes, bucket
shape, dtype)`` tuple lowers to its own XLA executable; the server compiles
on first use (AOT, via ``jax.jit(...).lower(...).compile()``) and caches the
result so the hot path is a dispatch, never a trace.  Eviction is LRU with a
configurable capacity -- a server cycling through more shapes than the
capacity trades recompiles for memory -- and the hit/miss/eviction counters
feed the server's metrics surface.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Tuple


@dataclass(frozen=True)
class ExecutableKey:
    """Everything that changes the compiled program.

    ``engine_spec`` must be the CANONICAL spec string
    (``str(repro.core.engines.EngineSpec.parse(...))``), so equivalent
    spellings -- ``"ntp"`` vs ``"ntp/jnp"`` -- hit one cache entry instead
    of compiling twice; ``request`` is ``(order,)`` for a pure-derivative
    grid or the axes tuple for a mixed partial; ``bucket`` is the padded
    batch size the executable was specialized to; ``mesh`` is the device
    mesh the executable was sharded over as ``((axis, size), ...)`` pairs
    (empty for the single-device program -- the same bucket compiled for a
    different mesh shape is a different executable).
    """

    net_id: str
    engine_spec: str
    kind: str                 # "grid" | "cross"
    request: Tuple[int, ...]
    bucket: int
    dtype: str
    mesh: Tuple[Tuple[str, int], ...] = ()


class ExecutableCache:
    """LRU map ExecutableKey -> compiled executable, with stats (thread-safe).

    ``get_or_build(key, builder)`` returns ``(executable, hit)``; the builder
    runs outside the lock guard only on a miss (compiles can take seconds --
    holding the lock would stall the stats surface, and a duplicate concurrent
    build is harmless: last writer wins, both executables are correct).
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[ExecutableKey, Callable]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_build(self, key: ExecutableKey,
                     builder: Callable[[], Callable]) -> Tuple[Callable, bool]:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key], True
            self.misses += 1
        fn = builder()
        with self._lock:
            self._entries[key] = fn
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        return fn, False

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: ExecutableKey) -> bool:
        with self._lock:
            return key in self._entries

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "size": len(self._entries),
                    "capacity": self.capacity}
