"""Batched high-order-derivative serving (the inference side of the stack).

``DerivativeServer`` fronts a trained network + derivative engine with a
request queue, shape-bucketed microbatching, a compiled-executable LRU
cache, explicit overload/timeout errors, and per-request metrics.  See
``examples/serve_operator.py`` for the end-to-end path (train -> checkpoint
-> serve) and ``benchmarks/serving_bench.py`` for the latency/throughput
benchmark riding the BENCH_*.json machinery.
"""

from .bucketing import (DEFAULT_BUCKETS, RequestTooLargeError, pad_fraction,
                        pad_to, pick_bucket)
from .cache import ExecutableCache, ExecutableKey
from .server import (DerivativeServer, RequestTimeoutError, ServedResult,
                     ServerClosedError, ServerOverloadedError)

__all__ = [
    "DEFAULT_BUCKETS", "DerivativeServer", "ExecutableCache",
    "ExecutableKey", "RequestTimeoutError", "RequestTooLargeError",
    "ServedResult", "ServerClosedError", "ServerOverloadedError",
    "pad_fraction", "pad_to", "pick_bucket",
]
