"""Batched high-order-derivative serving.

A :class:`DerivativeServer` holds one trained network + one derivative
engine and answers ``(x, order)`` / ``(x, axes)`` queries with derivative
tables -- the inference side of the paper's pitch: once n-TangentProp makes
order-n derivatives quasilinear, a trained PINN can return values *and*
derivatives per query batch in a hot loop.  The moving parts:

* requests enter a **bounded queue**; a full queue raises
  :class:`ServerOverloadedError` immediately (explicit backpressure, never a
  silent hang);
* a worker thread waits one **flush window** after the first arrival so
  concurrent clients with the same (kind, order/axes, dtype) **coalesce
  into one launch**, concatenated and zero-padded to the smallest admissible
  bucket (see :mod:`repro.serving.bucketing`);
* each (bucket, request) pair is compiled once and cached with LRU eviction
  (:mod:`repro.serving.cache`); input buffers are donated on accelerator
  backends so the padded batch is consumed in place;
* every response carries per-request metrics (queue wait, pad fraction,
  cache hit, end-to-end latency) and the server aggregates p50/p99 over a
  sliding window (:class:`repro.runtime.metrics.LatencyStats`).

Construction is either direct (``DerivativeServer(net, params, "ntp")``) or
from a training checkpoint (:meth:`DerivativeServer.from_checkpoint`, via
``ckpt.CheckpointManager`` -- the path ``examples/serve_operator.py``
drives end to end).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError
from concurrent.futures import TimeoutError as _FutureTimeout
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import DerivativeEngine, EngineSpec
from repro.core.network import Network
from repro.runtime.metrics import LatencyStats

from .bucketing import DEFAULT_BUCKETS, pad_fraction, pad_to, pick_bucket
from .cache import ExecutableCache, ExecutableKey


class ServerOverloadedError(RuntimeError):
    """The request queue is at capacity; retry with backoff."""


class RequestTimeoutError(TimeoutError):
    """The per-request deadline elapsed before a result was ready."""


class ServerClosedError(RuntimeError):
    """The server was closed while the request was pending."""


@dataclass(frozen=True)
class _GroupKey:
    """Requests coalesce only within a group: same computation, same dtype."""

    kind: str                  # "grid" | "cross"
    request: Tuple[int, ...]   # (order,) for grid, axes tuple for cross
    dtype: str


@dataclass
class ServedResult:
    """A derivative table plus the request's structured metrics.

    ``table`` is ``(d_in, order+1, N, d_out)`` for grid requests and
    ``(N, d_out)`` for cross requests, with N the caller's row count (pad
    rows are sliced off before delivery).
    """

    table: jnp.ndarray
    queue_wait_s: float
    latency_s: float
    bucket: int
    batch_rows: int            # live rows in the coalesced launch
    pad_fraction: float
    cache_hit: bool


@dataclass
class _Pending:
    x: jnp.ndarray
    group: _GroupKey
    future: Future
    t_submit: float


class DerivativeServer:
    """Serve ``engine.grid`` / ``engine.cross`` over a request queue.

    Parameters
    ----------
    net, params : the trained network and its parameter pytree.
    engine : engine spec string ("ntp", "ntp/pallas", "autodiff", ...) or a
        :class:`DerivativeEngine` instance.
    buckets : admissible padded batch sizes (sorted ascending).
    flush_window_s : how long the batcher waits after the first request of a
        batch for more coalescible requests (0 disables coalescing).
    max_queue : queue-depth bound; submits beyond it raise
        :class:`ServerOverloadedError`.
    cache_capacity : LRU capacity of the compiled-executable cache.
    mesh : optional ``jax.sharding.Mesh`` with a ``"data"`` axis; bucketed
        launches then run sharded over it (parameters replicated, the
        padded batch split across the data axis -- bit-identical tables for
        the ntp engines).  Every bucket must divide the data-axis size.
        The executable-cache key grows the mesh shape, so the same bucket
        compiled for different meshes never collides.
    autostart : start the worker thread (tests drive :meth:`_drain_once`
        synchronously with ``autostart=False``).
    """

    def __init__(self, net: Network, params, engine="ntp", *,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 flush_window_s: float = 0.002, max_queue: int = 256,
                 cache_capacity: int = 32, net_id: Optional[str] = None,
                 mesh=None, autostart: bool = True):
        self.net = net
        self.params = params
        self.engine = DerivativeEngine.from_spec(engine)
        # the CANONICAL spec string keys the executable cache: equivalent
        # spellings ("ntp" vs "ntp/jnp", "jet" vs "jax-jet") must share one
        # compiled entry, so the raw argument never flows into the key
        self.engine_spec = str(EngineSpec.parse(self.engine))
        self.buckets = tuple(sorted(int(b) for b in buckets))
        if not self.buckets:
            raise ValueError("need at least one bucket size")
        self.mesh = mesh
        if mesh is not None:
            if "data" not in mesh.shape:
                raise ValueError(f"serving mesh needs a 'data' axis, got "
                                 f"axes {tuple(mesh.shape)}")
            bad = [b for b in self.buckets if b % mesh.shape["data"]]
            if bad:
                raise ValueError(
                    f"buckets {bad} do not divide the {mesh.shape['data']}"
                    f"-way data axis; sharded launches need every padded "
                    f"batch to split evenly")
        # the mesh shape is part of every executable key (a sharded and a
        # single-device program at the same bucket are different binaries)
        self.mesh_key = tuple(
            (str(a), int(s)) for a, s in mesh.shape.items()) \
            if mesh is not None else ()
        self.flush_window_s = float(flush_window_s)
        self.max_queue = int(max_queue)
        self.net_id = net_id or (f"{type(net).__name__}"
                                 f"(d_in={net.d_in},d_out={net.d_out})")
        self.cache = ExecutableCache(capacity=cache_capacity)
        # donation frees the padded launch buffer in place on accelerators;
        # CPU ignores it, so skip there to keep logs clean
        self._donate = jax.default_backend() != "cpu"

        self._q: "deque[_Pending]" = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._worker: Optional[threading.Thread] = None

        self.queue_wait = LatencyStats()
        self.latency = LatencyStats()
        self._n_requests = 0
        self._n_batches = 0
        self._pad_sum = 0.0

        if autostart:
            self.start()

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def from_checkpoint(cls, directory: str, net: Network, *,
                        step: Optional[int] = None, dtype=jnp.float64,
                        engine="ntp", init_key: Optional[jax.Array] = None,
                        **kwargs) -> "DerivativeServer":
        """Restore ``net``'s parameters from a ``ckpt.CheckpointManager``
        directory (latest step by default) and serve them."""
        from repro.ckpt import CheckpointManager

        mgr = CheckpointManager(directory)
        if step is None:
            step = mgr.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoints under {directory!r}")
        like = net.init(init_key if init_key is not None
                        else jax.random.PRNGKey(0), dtype=dtype)
        params = mgr.restore(step, like)
        return cls(net, params, engine, **kwargs)

    def start(self) -> None:
        if self._worker is None:
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="derivative-server")
            self._worker.start()

    def close(self) -> None:
        """Stop the worker; pending requests fail with ServerClosedError."""
        with self._cv:
            self._closed = True
            pending = list(self._q)
            self._q.clear()
            self._cv.notify_all()
        for item in pending:
            try:
                item.future.set_exception(
                    ServerClosedError("server closed before the request ran"))
            except InvalidStateError:
                pass                     # client already cancelled it
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def __enter__(self) -> "DerivativeServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- submit
    def submit(self, x: jnp.ndarray, *, order: Optional[int] = None,
               axes: Optional[Sequence[int]] = None) -> Future:
        """Enqueue a request; returns a Future resolving to ServedResult.

        Exactly one of ``order`` (pure-derivative grid through that order)
        or ``axes`` (one mixed partial) must be given.
        """
        if (order is None) == (axes is None):
            raise ValueError("pass exactly one of order= or axes=")
        x = jnp.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.net.d_in:
            raise ValueError(f"x must be (N, {self.net.d_in}), "
                             f"got shape {tuple(x.shape)}")
        pick_bucket(x.shape[0], self.buckets)   # typed size/empty validation
        if order is not None:
            if order < 0:
                raise ValueError(f"order must be >= 0, got {order}")
            group = _GroupKey("grid", (int(order),), str(x.dtype))
        else:
            group = _GroupKey("cross", tuple(int(a) for a in axes),
                              str(x.dtype))

        item = _Pending(x=x, group=group, future=Future(),
                        t_submit=time.monotonic())
        with self._cv:
            if self._closed:
                raise ServerClosedError("server is closed")
            if len(self._q) >= self.max_queue:
                raise ServerOverloadedError(
                    f"request queue at capacity ({self.max_queue}); "
                    "shed load or raise max_queue")
            self._q.append(item)
            self._n_requests += 1
            self._cv.notify_all()
        return item.future

    def grid(self, x: jnp.ndarray, order: int, *,
             timeout: Optional[float] = None) -> jnp.ndarray:
        """Blocking pure-derivative table: (d_in, order+1, N, d_out)."""
        return self._result(self.submit(x, order=order), timeout).table

    def cross(self, x: jnp.ndarray, axes: Sequence[int], *,
              timeout: Optional[float] = None) -> jnp.ndarray:
        """Blocking mixed partial d^m f / dx_axes: (N, d_out)."""
        return self._result(self.submit(x, axes=axes), timeout).table

    @staticmethod
    def _result(future: Future, timeout: Optional[float]) -> ServedResult:
        try:
            return future.result(timeout)
        except _FutureTimeout:
            raise RequestTimeoutError(
                f"no result within {timeout}s (queue depth or compile "
                "stall; see server.metrics())") from None

    # -------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._q and not self._closed:
                    self._cv.wait()
                if self._closed:
                    return
            self._wait_flush_window()
            self._drain_once()

    def _wait_flush_window(self) -> None:
        """Give concurrent clients one window to coalesce; flush early when
        the queue already fills the largest bucket."""
        if self.flush_window_s <= 0:
            return
        deadline = time.monotonic() + self.flush_window_s
        with self._cv:
            while not self._closed:
                rows = sum(it.x.shape[0] for it in self._q)
                remaining = deadline - time.monotonic()
                if rows >= self.buckets[-1] or remaining <= 0:
                    return
                self._cv.wait(remaining)

    def _drain_once(self) -> bool:
        """Take one coalescible batch off the queue and execute it.

        Returns False when no batch ran (queue empty, or every admissible
        request had already been cancelled by its client).  The batch is the
        first live request plus every queued request sharing its group, in
        arrival order, up to the largest bucket; other groups stay queued
        for the next drain.  Dequeued requests are moved to the future's
        RUNNING state; ones a client cancelled while queued are dropped here
        -- fulfilling a cancelled future raises InvalidStateError, which
        would kill the worker thread.
        """
        with self._cv:
            batch, deferred, rows = [], [], 0
            while self._q:
                item = self._q.popleft()
                if batch and not (item.group == batch[0].group
                                  and rows + item.x.shape[0]
                                  <= self.buckets[-1]):
                    deferred.append(item)
                    continue
                if not item.future.set_running_or_notify_cancel():
                    continue             # cancelled while queued: drop
                batch.append(item)
                rows += item.x.shape[0]
            self._q.extend(deferred)
        if not batch:
            return False
        self._execute(batch)
        return True

    def _execute(self, batch: Sequence[_Pending]) -> None:
        t_batch = time.monotonic()
        group = batch[0].group
        ns = [it.x.shape[0] for it in batch]
        total = sum(ns)
        try:
            bucket = pick_bucket(total, self.buckets)
            # the launch buffer must be server-owned when it is donated: a
            # single exact-fit request would otherwise hand the CLIENT's
            # array to the executable, which deletes it in place (copy=
            # forces a fresh buffer in that one aliasing case; concatenation
            # and padding already produce fresh arrays)
            xp = pad_to(jnp.concatenate([it.x for it in batch], axis=0)
                        if len(batch) > 1 else batch[0].x, bucket,
                        copy=self._donate and len(batch) == 1)
            key = ExecutableKey(self.net_id, self.engine_spec, group.kind,
                                group.request, bucket, group.dtype,
                                self.mesh_key)
            fn, hit = self.cache.get_or_build(
                key, lambda: self._compile(group, bucket))
            out = fn(self.params, xp)
        except Exception as exc:                    # noqa: BLE001 -- fulfilled
            for it in batch:                        # per-request, not raised
                it.future.set_exception(exc)        # into the worker loop
            return

        frac = pad_fraction(total, bucket)
        self._n_batches += 1
        self._pad_sum += frac
        offset = 0
        for it, n in zip(batch, ns):
            seg = (out[:, :, offset:offset + n]
                   if group.kind == "grid" else out[offset:offset + n])
            offset += n
            now = time.monotonic()
            self.queue_wait.record(t_batch - it.t_submit)
            self.latency.record(now - it.t_submit)
            it.future.set_result(ServedResult(
                table=seg, queue_wait_s=t_batch - it.t_submit,
                latency_s=now - it.t_submit, bucket=bucket,
                batch_rows=total, pad_fraction=frac, cache_hit=hit))

    def _compile(self, group: _GroupKey, bucket: int):
        """AOT-compile the engine call at the bucket shape.

        The query buffer is donated on accelerator backends; _execute
        guarantees the server owns it (padding/concatenation build a fresh
        array per launch, and the one aliasing case -- a single exact-fit
        request -- is copied before launch), so donation never deletes a
        client's array.  CPU ignores donation, so skip it there to keep
        logs clean.
        """
        engine, net = self.engine, self.net
        if group.kind == "grid":
            order = group.request[0]

            def compute(p, x):
                return engine.grid(net, p, x, order)
        else:
            axes = group.request

            def compute(p, x):
                return engine.cross(net, p, x, axes)

        if self.mesh is not None:
            # the bucketed launch itself is the shard_map program: params
            # replicated, the padded batch split over the data axis (bucket
            # divisibility was validated at construction, and zero pad rows
            # are batch-independent, so sharding never changes live bits)
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            batch_axis = 2 if group.kind == "grid" else 0
            out_spec = P(*([None] * batch_axis + ["data"]))
            compute = shard_map(compute, mesh=self.mesh,
                                in_specs=(P(), P("data")),
                                out_specs=out_spec, check_rep=False)

        donate = (1,) if self._donate else ()
        x_spec = jax.ShapeDtypeStruct((bucket, net.d_in),
                                      np.dtype(group.dtype))
        return jax.jit(compute, donate_argnums=donate) \
            .lower(self.params, x_spec).compile()

    # ------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        """Aggregated server metrics: request/batch counts, queue-wait and
        end-to-end latency snapshots (p50/p99), mean pad fraction, and the
        executable-cache counters."""
        with self._cv:
            n_req, n_batch = self._n_requests, self._n_batches
            pad_sum, depth = self._pad_sum, len(self._q)
        return {
            "requests": n_req,
            "batches": n_batch,
            "queue_depth": depth,
            "queue_wait": self.queue_wait.snapshot(),
            "latency": self.latency.snapshot(),
            "pad_fraction_mean": (pad_sum / n_batch) if n_batch else 0.0,
            "cache": self.cache.stats(),
        }
