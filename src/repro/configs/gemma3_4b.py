"""gemma3-4b [hf:google/gemma-3-1b-pt; unverified]

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, 5:1 local:global
interleaving (window 1024), head_dim 256, GeGLU, RoPE theta 1M on global
layers (we use a single theta; noted adaptation)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262_144,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    mlp="geglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    # 8 q-heads don't divide the 16-way model axis and the 1024-window local
    # attention is a small flop share: replicated attention weights beat
    # hd-sharding 2x on the dominant roofline term (EXPERIMENTS.md 4.1)
    attn_sharding="replicate",
    source="[hf:google/gemma-3-1b-pt; unverified]",
)
