"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (GQA kv=8) d_ff=8192, MoE 128 experts top-1 interleaved
with dense layers (llama4's "interleaved MoE"; period 2), vocab 202048,
iRoPE-style 3 local(8192):1 global pattern, head_dim 128.  "Early fusion" is
a modality-frontend property; the assignment specifies the text backbone."""

from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    attn_pattern=("local", "local", "local", "global"),
    window=8192,
    mlp="swiglu",
    moe=MoECfg(n_experts=128, top_k=1, capacity_factor=1.25, period=2),
    rope_theta=500_000.0,
    tie_embeddings=False,
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
