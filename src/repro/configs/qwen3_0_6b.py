"""qwen3-0.6b [hf:Qwen/Qwen3-8B; hf]

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, qk_norm, head_dim 128,
SwiGLU, full global attention every layer."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151_936,
    attn_pattern=("global",),
    mlp="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    scan_group=2,
    source="[hf:Qwen/Qwen3-8B; hf]",
)
