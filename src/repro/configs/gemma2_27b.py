"""gemma2-27b [arXiv:2408.00118; hf]

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, local(4096):global
alternation, attn softcap 50, final logit softcap 30, head_dim 128, GeGLU."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256_000,
    attn_pattern=("local", "global"),
    window=4096,
    mlp="geglu",
    attn_softcap=50.0,
    logit_softcap=30.0,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="[arXiv:2408.00118; hf]",
)
