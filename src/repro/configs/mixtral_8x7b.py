"""mixtral-8x7b [arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8 experts top-2,
SWA window 4096 (mistral lineage), head_dim 128."""

from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32_000,
    attn_pattern=("local",),
    window=4096,
    mlp="swiglu",
    moe=MoECfg(n_experts=8, top_k=2, capacity_factor=1.25),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    scan_group=2,
    source="[arXiv:2401.04088; hf]",
)
