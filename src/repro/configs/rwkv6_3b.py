"""rwkv6-3b (Finch) [arXiv:2404.05892; hf]

32L d_model=2560 attn-free, d_ff=8960 channel-mix, vocab=65536,
data-dependent per-channel decay, head size 64 (40 heads)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # wkv heads = d_model / 64
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65_536,
    block_type="rwkv6",
    mlp="rwkv_channel_mix",
    tie_embeddings=True,
    scan_group=2,
    source="[arXiv:2404.05892; hf]",
)
