"""Architecture / shape configuration system.

``ArchConfig`` is the single composable description every model in
src/repro/models consumes; each assigned architecture instantiates one in its
own configs/<id>.py with the exact public-literature hyperparameters, plus a
``reduced()`` variant for CPU smoke tests.

Shapes are the assignment's four input regimes.  ``kind`` decides which step
is lowered: ``train`` -> train_step, ``prefill`` -> prefill forward,
``decode`` -> serve_step (1 new token against a seq_len-deep cache).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# model-parallel axis size on both assigned meshes (16x16 and 2x16x16);
# spec-selection helpers use it to pick shardable dims (heads vs head_dim).
MODEL_AXIS = 16


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    period: int = 1  # every `period`-th layer is MoE (llama4 interleaves dense/MoE)


@dataclass(frozen=True)
class EncoderCfg:
    """Encoder stack for enc-dec models (whisper); frontend is a stub that
    provides precomputed frame embeddings per the assignment."""

    n_layers: int
    seq: int = 1500  # whisper: 30 s of audio at 50 fps after the conv stub


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # block pattern: cycled over layers, e.g. ("local",)*5 + ("global",)
    attn_pattern: Tuple[str, ...] = ("global",)
    window: int = 4096               # sliding-window size for "local" layers
    mlp: str = "swiglu"              # swiglu | geglu | gelu_mlp
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    # moe
    moe: Optional[MoECfg] = None
    # ssm / hybrid
    block_type: str = "attn"         # attn | mamba2 | rwkv6
    ssm_state: int = 64
    ssm_heads: int = 0               # 0 -> d_inner // 64
    hybrid_shared_attn_every: int = 0  # zamba2: shared attn block period
    # enc-dec / vlm stubs
    encoder: Optional[EncoderCfg] = None
    vlm_image_tokens: int = 0        # llava anyres stub: patch embeds fused at front
    # numerics / layout
    dtype: str = "bfloat16"
    scan_group: int = 0              # layers per scan body; 0 -> len(attn_pattern)
    remat: bool = True               # activation checkpointing across layer groups
    attn_sharding: str = "auto"      # auto | replicate (perf knob; see section Perf)
    source: str = ""                 # [citation; verification tier]

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group(self) -> int:
        """Layers per scan body; layers beyond the last full group are
        unrolled as a remainder (gemma3: 34 = 5 groups of 6 + 4 rest)."""
        return self.scan_group or len(self.attn_pattern)

    def reduced(self, **overrides) -> "ArchConfig":
        """CPU-smoke-test scale: same family/topology, tiny dims."""
        pat = self.attn_pattern
        small = dict(
            n_layers=2 * len(pat) if self.hybrid_shared_attn_every == 0 else 4,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            window=16,
            moe=MoECfg(4, self.moe.top_k, self.moe.capacity_factor) if self.moe else None,
            ssm_state=16,
            ssm_heads=2,
            hybrid_shared_attn_every=2 if self.hybrid_shared_attn_every else 0,
            encoder=EncoderCfg(n_layers=2, seq=32) if self.encoder else None,
            vlm_image_tokens=8 if self.vlm_image_tokens else 0,
            dtype="float32",
            remat=False,
            scan_group=2 if self.hybrid_shared_attn_every else 0,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeCfg("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeCfg("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCfg("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCfg("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# archs whose every layer is full global attention: long_500k skipped
# (assignment: "skip for pure full-attention archs", DESIGN.md section 4)
PURE_FULL_ATTENTION = frozenset({"qwen3-0.6b", "granite-3-2b", "whisper-large-v3"})


def shape_applicable(arch: ArchConfig, shape: ShapeCfg) -> bool:
    if shape.name == "long_500k" and arch.name in PURE_FULL_ATTENTION:
        return False
    return True
