"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
SWA 4096, head_dim 128.  Anyres tiling is a STUB per the assignment:
input_specs() provides 2880 precomputed patch embeddings (5 tiles x 576)
fused at the front of the token sequence through a learned projector."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32_000,
    attn_pattern=("local",),
    window=4096,
    mlp="swiglu",
    vlm_image_tokens=2880,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    scan_group=2,
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]",
)
