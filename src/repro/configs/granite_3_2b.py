"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base; hf]

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155, SwiGLU, full attention."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49_155,
    attn_pattern=("global",),
    mlp="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    scan_group=2,
    source="[hf:ibm-granite/granite-3.0-2b-base; hf]",
)
