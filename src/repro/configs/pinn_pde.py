"""PDE-operator PINN architecture: the multi-PDE scenario surface
(heat / wave / KdV / Allen-Cahn / 2-D Poisson / advection-diffusion /
Navier-Stokes streamfunction / Gray-Scott; mixed partials up to the 4th-order
psi_xxyy are served by polarization, and Gray-Scott trains one d_out=2
network against a stacked two-equation residual).

Wider than the paper's 3x24 Burgers net because the 2-D manufactured
solutions carry more structure; registered so --arch pinn-pde drives the
operator workloads through the same launcher surface as pinn-mlp.  The
training-side knobs live on ``repro.pinn.OperatorRunConfig``: ``engine``
takes a derivative-engine spec ("ntp", "ntp/pallas", "autodiff") and
``network`` a registered architecture built on the jet-module layer
("dense", "mlp", "residual", "fourier", "transformer" -- see
``repro.core.network`` / ``repro.core.modules``); transformer extras ride
``net_kwargs`` (``{"n_heads": 2, "mlp_ratio": 2, "mask": None}``; ``mask``
accepts ``None``/"none", ``"causal"``, or ``("local", W)`` and flows to
``SelfAttention`` -- every variant runs through the same single-launch
flash-jet kernel under ``ntp/pallas``; the attention trunk tokenizes the
d_in input coordinates, so n_heads/head_dim below describe the default
attention shape, not a sequence model).  d_in follows the operator
(2 for the (t, x) PDEs, 3 for advection-diffusion's (t, x, y))."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pinn-pde",
    family="pinn",
    n_layers=3,
    d_model=32,          # width (d_model for network="transformer")
    n_heads=2,           # transformer trunk default (width % n_heads == 0)
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,             # transformer feed-forward = mlp_ratio(2) * width
    vocab=2,             # d_in = 2 (t, x) or (x, y); d_out follows op.d_out
    attn_pattern=("global",),
    dtype="float64",
    source="[operator subsystem default: 3 hidden layers x 32 neurons, tanh;"
           " transformer trunk: 2 heads, mlp_ratio 2 over coordinate tokens]",
)
