"""whisper-large-v3 [arXiv:2212.04356; unverified]

Enc-dec, 32+32L d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
Conv frontend is a STUB per the assignment: input_specs() provides the
precomputed 1500-frame embeddings.  seq_len in shapes refers to the decoder;
the encoder is fixed at 1500 frames.  Adaptations (DESIGN.md): rmsnorm+gelu
in place of layernorm+gelu, RoPE in place of learned/sinusoidal positions."""

from .base import ArchConfig, EncoderCfg

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51_866,
    attn_pattern=("global",),
    mlp="gelu_mlp",
    encoder=EncoderCfg(n_layers=32, seq=1500),
    rope_theta=10_000.0,
    tie_embeddings=True,
    scan_group=2,
    source="[arXiv:2212.04356; unverified]",
)
