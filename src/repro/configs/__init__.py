"""Config registry: ``get_arch(name)`` / ``--arch <id>``."""

from __future__ import annotations

from .base import (LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K, DECODE_32K,
                   ArchConfig, EncoderCfg, MoECfg, ShapeCfg, shape_applicable)


def _load_all():
    from . import (gemma2_27b, gemma3_4b, granite_3_2b, llama4_maverick,
                   llava_next_mistral_7b, mixtral_8x7b, pinn_mlp, pinn_pde,
                   qwen3_0_6b, rwkv6_3b, whisper_large_v3, zamba2_2_7b)
    mods = [gemma3_4b, qwen3_0_6b, gemma2_27b, granite_3_2b, mixtral_8x7b,
            llama4_maverick, zamba2_2_7b, whisper_large_v3,
            llava_next_mistral_7b, rwkv6_3b, pinn_mlp, pinn_pde]
    return {m.CONFIG.name: m.CONFIG for m in mods}


_REGISTRY = None


def registry() -> dict[str, ArchConfig]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _load_all()
    return _REGISTRY


def get_arch(name: str) -> ArchConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")
    return reg[name]


ASSIGNED = (
    "gemma3-4b", "qwen3-0.6b", "gemma2-27b", "granite-3-2b", "mixtral-8x7b",
    "llama4-maverick-400b-a17b", "zamba2-2.7b", "whisper-large-v3",
    "llava-next-mistral-7b", "rwkv6-3b",
)
