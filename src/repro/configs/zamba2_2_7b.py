"""zamba2-2.7b [arXiv:2411.15242; hf]

54 Mamba2 blocks d_model=2560 (d_inner 5120, headdim 64, state 64) plus a
*shared* full-attention+MLP block (32H MHA kv=32, d_ff=10240) applied every 6
mamba blocks with tied weights -- the zamba2 topology.  vocab 32000."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32_000,
    block_type="mamba2",
    ssm_state=64,
    ssm_heads=80,            # d_inner 5120 / headdim 64
    hybrid_shared_attn_every=6,
    mlp="gelu_mlp",
    rope_theta=10_000.0,
    tie_embeddings=True,
    scan_group=6,
    source="[arXiv:2411.15242; hf]",
)
