"""The paper's own architecture: tanh MLP for PINN training (3x24 default).

Not part of the assigned LM pool; registered so --arch pinn-mlp drives the
paper-faithful experiments through the same launcher."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pinn-mlp",
    family="pinn",
    n_layers=3,
    d_model=24,          # width
    n_heads=1,
    n_kv_heads=1,
    head_dim=1,
    d_ff=24,
    vocab=1,             # d_in = d_out = 1 (self-similar Burgers profile)
    attn_pattern=("global",),
    dtype="float64",
    source="[paper section IV: 3 hidden layers x 24 neurons, tanh]",
)
