"""Jet-traceable network architectures for the derivative engines.

The paper states n-TangentProp for uniform-width dense MLPs, but the jet
algebra (core/jet.py) is architecture-agnostic: anything built from linear
maps, Cauchy products, and registered smooth activations pushes a truncated
Taylor jet forward in the same O(n p(n) M).  This module makes that a
first-class abstraction: a :class:`Network` is an object with

* ``init(key, dtype)``            -- parameter pytree construction;
* ``apply(params, x, unroll=)``   -- plain forward (N, d_in) -> (N, d_out).
  ``unroll=True`` must avoid ``lax.scan`` so ``jax.experimental.jet`` (no
  scan rule) can trace it -- the :class:`~repro.core.engines.JaxJetEngine`
  oracle depends on this;
* ``jet_apply(params, jet, impl=)`` -- push a :class:`repro.core.jet.Jet`
  of the inputs through the network.  ``impl="jnp"`` runs the reference jet
  algebra; ``impl="pallas"`` routes every dense layer through the fused
  Pallas kernel dispatch (kernels/ops.jet_dense), which falls back to the
  reference automatically for activations without a kernel table.

Shipped networks:

=================  ==========================================================
DenseMLP           uniform-width MLP over :class:`repro.core.ntp.MLPParams`
                   (fully backward-compatible with the seed API)
MLP                variable per-layer widths
ResidualMLP        pre-activation skip connections ``h <- h + act(W h + b)``
FourierFeatureMLP  random-feature embedding ``[sin 2pi Bx, cos 2pi Bx]`` in
                   front of an MLP trunk (the standard PINN spectral-bias
                   fix; B is fixed, not trained)
=================  ==========================================================

New architectures implement the three-method protocol (or register a factory
with :func:`register_network`) and every :class:`DerivativeEngine`, the
operator subsystem, ``pinn_loss``, and ``train_operator`` consume them
without further plumbing.  ``d_out`` is unconstrained: a d_out > 1 network
solves a vector-valued PDE system (one shared trunk, one output column per
unknown field), and the engines carry the component axis through every
derivative.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from . import jet as J
from .activations import PRIMALS
from .ntp import MLPParams, init_mlp, mlp_apply, ntp_jet, xavier_uniform

Params = Any  # parameter pytree; its structure is owned by the network


@runtime_checkable
class Network(Protocol):
    """Anything the derivative engines can differentiate."""

    d_in: int
    d_out: int
    activation: str

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params: ...

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray: ...

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet: ...


# ---------------------------------------------------------------------------
# shared building blocks
# ---------------------------------------------------------------------------

def _dense_jet(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               activation: str | None, impl: str) -> jnp.ndarray:
    """One dense layer (+ optional activation) on a raw coefficient stack."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.jet_dense(coeffs, w, b, activation)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r} (want 'jnp' or 'pallas')")
    out = J.linear(J.Jet(coeffs), w, b)
    if activation is not None:
        out = J.compose(out, activation)
    return out.coeffs


# ---------------------------------------------------------------------------
# DenseMLP: the paper's architecture, over the seed MLPParams pytree
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DenseMLP:
    """Uniform-width MLP; params are the seed :class:`MLPParams` NamedTuple,
    so everything that holds an ``MLPParams`` works unchanged."""

    d_in: int
    width: int
    depth: int
    d_out: int
    activation: str = "tanh"

    @classmethod
    def from_params(cls, params: MLPParams, activation: str = "tanh") -> "DenseMLP":
        """Recover the architecture from a parameter pytree (for call sites
        that hold only the seed NamedTuple, e.g. the legacy ntp_grid/cross
        wrappers in core/ntp.py)."""
        return cls(d_in=params.w_in.shape[0], width=params.w_in.shape[1],
                   depth=params.w_hidden.shape[0] + 1,
                   d_out=params.w_out.shape[1], activation=activation)

    def init(self, key: jax.Array, dtype=jnp.float32) -> MLPParams:
        return init_mlp(key, self.d_in, self.width, self.depth, self.d_out,
                        dtype=dtype)

    def apply(self, params: MLPParams, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        return mlp_apply(params, x, self.activation, unroll=unroll)

    def jet_apply(self, params: MLPParams, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        return ntp_jet(params, jet, activation=self.activation, impl=impl)


# ---------------------------------------------------------------------------
# MLP: variable per-layer widths
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLP:
    """Fully-connected net with arbitrary layer widths.

    ``widths = (d_in, h_1, ..., h_L, d_out)``; params are a tuple of (w, b)
    pairs, one per layer.  Hidden layers are activated, the last is linear.
    """

    widths: Tuple[int, ...]
    activation: str = "tanh"

    def __post_init__(self):
        if len(self.widths) < 2:
            raise ValueError("MLP needs at least (d_in, d_out) widths")

    @property
    def d_in(self) -> int:
        return self.widths[0]

    @property
    def d_out(self) -> int:
        return self.widths[-1]

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        ks = jax.random.split(key, len(self.widths) - 1)
        return tuple((xavier_uniform(k, fi, fo, dtype), jnp.zeros((fo,), dtype))
                     for k, fi, fo in zip(ks, self.widths[:-1], self.widths[1:]))

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        act = PRIMALS[self.activation]
        h = x
        for w, b in params[:-1]:
            h = act(h @ w + b)
        w, b = params[-1]
        return h @ w + b

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        coeffs = jet.coeffs
        for w, b in params[:-1]:
            coeffs = _dense_jet(coeffs, w, b, self.activation, impl)
        w, b = params[-1]
        return J.Jet(_dense_jet(coeffs, w, b, None, impl))


# ---------------------------------------------------------------------------
# ResidualMLP: skip connections (jet addition is exact)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ResidualMLP:
    """``h_0 = act(W_in x + b_in)``; ``h_j = h_{j-1} + act(W_j h_{j-1} + b_j)``
    for ``depth`` blocks; linear readout.  Residual adds are coefficient-wise
    on the jet, so the derivative cost matches the plain MLP layer-for-layer.
    """

    d_in: int
    width: int
    depth: int
    d_out: int
    activation: str = "tanh"

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        ks = jax.random.split(key, self.depth + 2)
        return {
            "w_in": xavier_uniform(ks[0], self.d_in, self.width, dtype),
            "b_in": jnp.zeros((self.width,), dtype),
            "blocks": tuple(
                (xavier_uniform(ks[1 + j], self.width, self.width, dtype),
                 jnp.zeros((self.width,), dtype)) for j in range(self.depth)),
            "w_out": xavier_uniform(ks[-1], self.width, self.d_out, dtype),
            "b_out": jnp.zeros((self.d_out,), dtype),
        }

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        act = PRIMALS[self.activation]
        h = act(x @ params["w_in"] + params["b_in"])
        for w, b in params["blocks"]:
            h = h + act(h @ w + b)
        return h @ params["w_out"] + params["b_out"]

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        coeffs = _dense_jet(jet.coeffs, params["w_in"], params["b_in"],
                            self.activation, impl)
        for w, b in params["blocks"]:
            coeffs = coeffs + _dense_jet(coeffs, w, b, self.activation, impl)
        return J.Jet(_dense_jet(coeffs, params["w_out"], params["b_out"],
                                None, impl))


# ---------------------------------------------------------------------------
# FourierFeatureMLP: random-feature embedding against spectral bias
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FourierFeatureMLP:
    """``gamma(x) = [sin(2pi B x), cos(2pi B x)]`` with fixed Gaussian
    ``B ~ N(0, scale^2)`` of shape (d_in, n_features), then an MLP trunk on
    the 2*n_features embedding (Tancik et al. 2020; the standard PINN cure
    for spectral bias).  B is excluded from gradients (stop_gradient), and
    the embedding jet is exact: ``sin`` composes through Faa di Bruno and
    ``cos z = sin(z + pi/2)`` reuses the same table.
    """

    d_in: int
    width: int
    depth: int
    d_out: int
    n_features: int = 16
    feature_scale: float = 1.0
    activation: str = "tanh"

    def _trunk(self) -> MLP:
        widths = (2 * self.n_features,) + (self.width,) * self.depth \
            + (self.d_out,)
        return MLP(widths, self.activation)

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        kb, km = jax.random.split(key)
        B = self.feature_scale * jax.random.normal(
            kb, (self.d_in, self.n_features), dtype)
        return {"B": B, "mlp": self._trunk().init(km, dtype)}

    def _freqs(self, params: Params) -> jnp.ndarray:
        return 2.0 * math.pi * jax.lax.stop_gradient(params["B"])

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        z = x @ self._freqs(params)
        feats = jnp.concatenate([jnp.sin(z), jnp.cos(z)], axis=-1)
        return self._trunk().apply(params["mlp"], feats, unroll=unroll)

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        z = J.linear(jet, self._freqs(params))
        s = J.compose(z, "sin")
        c = J.compose(J.add(z, 0.5 * math.pi), "sin")   # cos z = sin(z + pi/2)
        feats = J.jmap(lambda a, b: jnp.concatenate([a, b], axis=-1), s, c)
        return self._trunk().jet_apply(params["mlp"], feats, impl=impl)


# ---------------------------------------------------------------------------
# registry: named factories for configs / CLIs
# ---------------------------------------------------------------------------

NetworkFactory = Callable[..., Network]

_NETWORKS: Dict[str, NetworkFactory] = {}


def register_network(name: str, factory: NetworkFactory) -> None:
    if name in _NETWORKS:
        raise ValueError(f"network {name!r} already registered")
    _NETWORKS[name] = factory


def network_names() -> Tuple[str, ...]:
    return tuple(sorted(_NETWORKS))


def make_network(kind: str, *, d_in: int, d_out: int, width: int, depth: int,
                 activation: str = "tanh", **kwargs) -> Network:
    """Build a registered network from the uniform (width, depth) vocabulary
    used by configs and CLIs; extra kwargs go to the factory."""
    if kind not in _NETWORKS:
        raise KeyError(f"unknown network {kind!r}; known: {network_names()}")
    return _NETWORKS[kind](d_in=d_in, d_out=d_out, width=width, depth=depth,
                           activation=activation, **kwargs)


register_network("dense", DenseMLP)
register_network("mlp", lambda *, d_in, d_out, width, depth, activation="tanh",
                 **kw: MLP((d_in,) + (width,) * depth + (d_out,), activation))
register_network("residual", ResidualMLP)
register_network("fourier", FourierFeatureMLP)
