"""Jet-traceable network architectures for the derivative engines.

The paper states n-TangentProp for uniform-width dense MLPs, but the jet
algebra (core/jet.py) is architecture-agnostic: anything built from linear
maps, Cauchy products, and registered smooth activations pushes a truncated
Taylor jet forward in the same O(n p(n) M).  This module makes that a
first-class abstraction: a :class:`Network` is an object with

* ``init(key, dtype)``            -- parameter pytree construction;
* ``apply(params, x, unroll=)``   -- plain forward (N, d_in) -> (N, d_out).
  ``unroll=True`` must avoid ``lax.scan`` so ``jax.experimental.jet`` (no
  scan rule) can trace it -- the :class:`~repro.core.engines.JaxJetEngine`
  oracle depends on this;
* ``jet_apply(params, jet, impl=)`` -- push a :class:`repro.core.jet.Jet`
  of the inputs through the network.  ``impl="jnp"`` runs the reference jet
  algebra; ``impl="pallas"`` routes every dense layer through the fused
  Pallas kernel dispatch (kernels/ops.jet_dense), which falls back to the
  reference automatically for activations without a kernel table.

Every shipped network is a **thin composition over the jet-module layer**
(:mod:`repro.core.modules`): it declares a module graph (``Sequential`` /
``Residual`` over registered leaves) and adapts its public parameter pytree
onto that graph, so no architecture hand-writes jet plumbing -- the leaves
own the jet rules, the networks own only structure and the (stable) param
layout.

=================  ==========================================================
DenseMLP           uniform-width MLP over :class:`repro.core.ntp.MLPParams`
                   (fully backward-compatible with the seed API)
MLP                variable per-layer widths
ResidualMLP        pre-activation skip connections ``h <- h + act(W h + b)``
FourierFeatureMLP  random-feature embedding ``[sin 2pi Bx, cos 2pi Bx]`` in
                   front of an MLP trunk (the standard PINN spectral-bias
                   fix; B is fixed, not trained)
Transformer        pre-norm self-attention trunk over coordinate tokens
                   (the first non-MLP PINN architecture; softmax/einsum/
                   rms_norm all inside the quasilinear jet algebra)
=================  ==========================================================

New architectures compose modules the same way (or register a factory with
:func:`register_network`) and every :class:`DerivativeEngine`, the operator
subsystem, ``pinn_loss``, and ``train_operator`` consume them without
further plumbing.  ``d_out`` is unconstrained: a d_out > 1 network solves a
vector-valued PDE system (one shared trunk, one output column per unknown
field), and the engines carry the component axis through every derivative.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from . import jet as J
from .modules import (CoordinateEmbedding, Dense, FourierFeatures, MLPBlock,
                      Module, Residual, RMSNorm, SelfAttention, Sequential,
                      TokenPool)
from .ntp import MLPParams, init_mlp, mlp_apply, xavier_uniform

Params = Any  # parameter pytree; its structure is owned by the network


@runtime_checkable
class Network(Protocol):
    """Anything the derivative engines can differentiate."""

    d_in: int
    d_out: int
    activation: str

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params: ...

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray: ...

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet: ...


class _Composed:
    """Mixin: a network that IS a module graph.

    Subclasses provide ``_graph()`` (the module composition) and, when the
    public parameter pytree is not already the graph's tuple layout,
    ``_graph_params(params)`` to adapt it (a free re-view, never a copy).
    ``apply``/``jet_apply`` then delegate to the graph, so the network never
    hand-writes jet plumbing.
    """

    def _graph(self) -> Module:
        raise NotImplementedError

    def _graph_params(self, params: Params) -> Params:
        return params

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        return self._graph().apply(self._graph_params(params), x,
                                   unroll=unroll)

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        return self._graph().jet_apply(self._graph_params(params), jet,
                                       impl=impl)


# ---------------------------------------------------------------------------
# DenseMLP: the paper's architecture, over the seed MLPParams pytree
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DenseMLP(_Composed):
    """Uniform-width MLP; params are the seed :class:`MLPParams` NamedTuple,
    so everything that holds an ``MLPParams`` works unchanged -- the stacked
    pytree is adapted onto a Sequential of Dense leaves at call time."""

    d_in: int
    width: int
    depth: int
    d_out: int
    activation: str = "tanh"

    @classmethod
    def from_params(cls, params: MLPParams, activation: str = "tanh") -> "DenseMLP":
        """Recover the architecture from a parameter pytree (for call sites
        that hold only the seed NamedTuple, e.g. the legacy ntp_grid/cross
        wrappers in core/ntp.py)."""
        return cls(d_in=params.w_in.shape[0], width=params.w_in.shape[1],
                   depth=params.w_hidden.shape[0] + 1,
                   d_out=params.w_out.shape[1], activation=activation)

    def init(self, key: jax.Array, dtype=jnp.float32) -> MLPParams:
        return init_mlp(key, self.d_in, self.width, self.depth, self.d_out,
                        dtype=dtype)

    def _graph(self) -> Module:
        hidden = tuple(Dense(self.width, self.width, self.activation)
                       for _ in range(self.depth - 1))
        return Sequential((Dense(self.d_in, self.width, self.activation),
                           *hidden, Dense(self.width, self.d_out, None)))

    def _graph_params(self, p: MLPParams) -> Params:
        hidden = tuple((p.w_hidden[i], p.b_hidden[i])
                       for i in range(p.w_hidden.shape[0]))
        return ((p.w_in, p.b_in), *hidden, (p.w_out, p.b_out))

    def apply(self, params: MLPParams, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        # the stacked pytree admits a lax.scan over hidden layers, keeping
        # the primal forward's compile time O(1) in depth; unroll=True (for
        # jax.experimental.jet, which has no scan rule) python-unrolls
        return mlp_apply(params, x, self.activation, unroll=unroll)


# ---------------------------------------------------------------------------
# MLP: variable per-layer widths
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLP(_Composed):
    """Fully-connected net with arbitrary layer widths.

    ``widths = (d_in, h_1, ..., h_L, d_out)``; params ARE the module
    graph's: a tuple of (w, b) pairs, one per Dense leaf.  Hidden layers are
    activated, the last is linear.
    """

    widths: Tuple[int, ...]
    activation: str = "tanh"

    def __post_init__(self):
        if len(self.widths) < 2:
            raise ValueError("MLP needs at least (d_in, d_out) widths")

    @property
    def d_in(self) -> int:
        return self.widths[0]

    @property
    def d_out(self) -> int:
        return self.widths[-1]

    def _graph(self) -> Module:
        last = len(self.widths) - 2
        return Sequential(tuple(
            Dense(fi, fo, self.activation if i < last else None)
            for i, (fi, fo) in enumerate(zip(self.widths[:-1],
                                             self.widths[1:]))))

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return self._graph().init(key, dtype)


# ---------------------------------------------------------------------------
# ResidualMLP: skip connections (jet addition is exact)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ResidualMLP(_Composed):
    """``h_0 = act(W_in x + b_in)``; ``h_j = h_{j-1} + act(W_j h_{j-1} + b_j)``
    for ``depth`` blocks; linear readout.  The graph is Dense ->
    Residual(Dense) x depth -> Dense; residual adds are coefficient-wise on
    the jet, so the derivative cost matches the plain MLP layer-for-layer.
    """

    d_in: int
    width: int
    depth: int
    d_out: int
    activation: str = "tanh"

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        ks = jax.random.split(key, self.depth + 2)
        return {
            "w_in": xavier_uniform(ks[0], self.d_in, self.width, dtype),
            "b_in": jnp.zeros((self.width,), dtype),
            "blocks": tuple(
                (xavier_uniform(ks[1 + j], self.width, self.width, dtype),
                 jnp.zeros((self.width,), dtype)) for j in range(self.depth)),
            "w_out": xavier_uniform(ks[-1], self.width, self.d_out, dtype),
            "b_out": jnp.zeros((self.d_out,), dtype),
        }

    def _graph(self) -> Module:
        blocks = tuple(
            Residual(Dense(self.width, self.width, self.activation))
            for _ in range(self.depth))
        return Sequential((Dense(self.d_in, self.width, self.activation),
                           *blocks, Dense(self.width, self.d_out, None)))

    def _graph_params(self, p: Params) -> Params:
        return ((p["w_in"], p["b_in"]), *p["blocks"],
                (p["w_out"], p["b_out"]))


# ---------------------------------------------------------------------------
# FourierFeatureMLP: random-feature embedding against spectral bias
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FourierFeatureMLP(_Composed):
    """``gamma(x) = [sin(2pi B x), cos(2pi B x)]`` with fixed Gaussian
    ``B ~ N(0, scale^2)`` of shape (d_in, n_features), then an MLP trunk on
    the 2*n_features embedding (Tancik et al. 2020; the standard PINN cure
    for spectral bias).  The graph is FourierFeatures -> Dense stack; B is
    excluded from gradients (stop_gradient) and the embedding jet is exact.
    """

    d_in: int
    width: int
    depth: int
    d_out: int
    n_features: int = 16
    feature_scale: float = 1.0
    activation: str = "tanh"

    def _trunk(self) -> MLP:
        widths = (2 * self.n_features,) + (self.width,) * self.depth \
            + (self.d_out,)
        return MLP(widths, self.activation)

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        kb, km = jax.random.split(key)
        B = FourierFeatures(self.d_in, self.n_features,
                            self.feature_scale).init(kb, dtype)
        return {"B": B, "mlp": self._trunk().init(km, dtype)}

    def _graph(self) -> Module:
        embed = FourierFeatures(self.d_in, self.n_features,
                                self.feature_scale)
        return Sequential((embed, *self._trunk()._graph().modules))

    def _graph_params(self, p: Params) -> Params:
        return (p["B"], *p["mlp"])


# ---------------------------------------------------------------------------
# Transformer: pre-norm self-attention trunk over coordinate tokens
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Transformer(_Composed):
    """Attention PINN trunk: each input coordinate becomes a token
    (:class:`CoordinateEmbedding`, whose per-coordinate rows double as
    learned positional encodings), ``depth`` pre-norm blocks of
    ``Residual(RMSNorm -> SelfAttention)`` then ``Residual(RMSNorm ->
    MLPBlock)`` mix the tokens, and a final RMSNorm -> mean token pool ->
    linear head reads out ``d_out`` components.

    Everything is smooth and jet-traceable: attention scores and value
    mixing are jet x jet Cauchy-convolved einsums, softmax runs on the
    exp/div power-series recurrences, RMSNorm on the rsqrt recurrence -- so
    the whole trunk keeps the paper's O(n p(n) M) derivative cost, versus
    O(M^n) for nested autodiff through attention.  Params are the module
    graph's native tuple (this is the first network with no legacy pytree
    to preserve).
    """

    d_in: int
    width: int               # token embedding dim (d_model)
    depth: int               # number of attention + MLP block pairs
    d_out: int
    n_heads: int = 2
    mlp_ratio: int = 2       # feed-forward hidden dim = mlp_ratio * width
    activation: str = "tanh"
    mask: Any = None         # None | "causal" | ("local", window)

    def __post_init__(self):
        if self.width % self.n_heads:
            raise ValueError(f"width={self.width} not divisible by "
                             f"n_heads={self.n_heads}")
        # validate + canonicalize once here (SelfAttention would anyway):
        # configs pass lists, the dataclass must stay hashable
        probe = SelfAttention(self.width, self.n_heads, self.mask)
        object.__setattr__(self, "mask", probe.mask)

    def _graph(self) -> Module:
        mods = [CoordinateEmbedding(self.d_in, self.width)]
        for _ in range(self.depth):
            mods.append(Residual(Sequential((
                RMSNorm(self.width),
                SelfAttention(self.width, self.n_heads, self.mask)))))
            mods.append(Residual(Sequential((
                RMSNorm(self.width),
                MLPBlock(self.width, self.mlp_ratio * self.width,
                         self.activation)))))
        mods += [RMSNorm(self.width), TokenPool(),
                 Dense(self.width, self.d_out, None)]
        return Sequential(tuple(mods))

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return self._graph().init(key, dtype)


# ---------------------------------------------------------------------------
# registry: named factories for configs / CLIs
# ---------------------------------------------------------------------------

NetworkFactory = Callable[..., Network]

_NETWORKS: Dict[str, NetworkFactory] = {}


def register_network(name: str, factory: NetworkFactory) -> None:
    if name in _NETWORKS:
        raise ValueError(f"network {name!r} already registered")
    _NETWORKS[name] = factory


def network_names() -> Tuple[str, ...]:
    return tuple(sorted(_NETWORKS))


def make_network(kind: str, *, d_in: int, d_out: int, width: int, depth: int,
                 activation: str = "tanh", **kwargs) -> Network:
    """Build a registered network from the uniform (width, depth) vocabulary
    used by configs and CLIs; extra kwargs go to the factory."""
    if kind not in _NETWORKS:
        raise KeyError(f"unknown network {kind!r}; known: {network_names()}")
    return _NETWORKS[kind](d_in=d_in, d_out=d_out, width=width, depth=depth,
                           activation=activation, **kwargs)


register_network("dense", DenseMLP)
register_network("mlp", lambda *, d_in, d_out, width, depth, activation="tanh",
                 **kw: MLP((d_in,) + (width,) * depth + (d_out,), activation))
register_network("residual", ResidualMLP)
register_network("fourier", FourierFeatureMLP)
register_network("transformer", Transformer)
