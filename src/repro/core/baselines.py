"""Baselines the paper compares against, plus independent oracles.

* ``nested_autodiff``      -- the standard PINN practice the paper benchmarks:
                              n nested reverse-mode sweeps (O(M^n) graph).
* ``nested_jacfwd``        -- forward-over-forward nesting; same asymptotic
                              blow-up, often faster constants.  Included so the
                              benchmark shows the *best* autodiff baseline.
* ``jax_jet_derivatives``  -- jax.experimental.jet (JAX's Taylor mode): an
                              independent quasilinear implementation used as a
                              correctness oracle for ours.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .ntp import MLPParams, mlp_apply


def _scalar_fn(params: MLPParams, activation: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """x (d_in,) -> scalar along the first output coordinate sum (as the paper's
    PINN nets have d_out == 1, this is just u(x))."""

    def f(x):
        return mlp_apply(params, x[None, :], activation)[0].sum()

    return f


def nested_autodiff(params: MLPParams, x: jnp.ndarray, order: int,
                    tangent: jnp.ndarray | None = None,
                    activation: str = "tanh") -> jnp.ndarray:
    """(order+1, batch, 1) directional derivatives via n nested jax.grad."""
    if tangent is None:
        tangent = jnp.ones_like(x)

    def along(xi, vi):
        f = _scalar_fn(params, activation)

        def g(t):
            return f(xi + t * vi)

        outs = []
        h = g
        for _ in range(order + 1):
            outs.append(h)
            h = jax.grad(h)
        return jnp.stack([o(0.0) for o in outs])

    return jax.vmap(along)(x, tangent).T[..., None]


def nested_jacfwd(params: MLPParams, x: jnp.ndarray, order: int,
                  tangent: jnp.ndarray | None = None,
                  activation: str = "tanh") -> jnp.ndarray:
    """Same quantity via nested forward-mode (jvp towers)."""
    if tangent is None:
        tangent = jnp.ones_like(x)

    def along(xi, vi):
        f = _scalar_fn(params, activation)

        def g(t):
            return f(xi + t * vi)

        outs = []
        h = g
        for _ in range(order + 1):
            outs.append(h)
            prev = h

            def deriv(t, prev=prev):
                return jax.jvp(prev, (t,), (jnp.ones_like(t),))[1]

            h = deriv
        return jnp.stack([o(jnp.asarray(0.0, x.dtype)) for o in outs])

    return jax.vmap(along)(x, tangent).T[..., None]


def jax_jet_derivatives(params: MLPParams, x: jnp.ndarray, order: int,
                        tangent: jnp.ndarray | None = None,
                        activation: str = "tanh") -> jnp.ndarray:
    """(order+1, batch, d_out) raw derivatives via jax.experimental.jet."""
    from jax.experimental import jet as jjet

    if tangent is None:
        tangent = jnp.ones_like(x)
    if order == 0:
        return mlp_apply(params, x, activation)[None]

    def f(xx):
        return mlp_apply(params, xx, activation, unroll=True)

    # series seeds raw derivatives of the input curve x + t v: (v, 0, ..., 0)
    series = [tangent] + [jnp.zeros_like(x) for _ in range(order - 1)]
    y0, yseries = jjet.jet(f, (x,), ((series),))
    return jnp.stack([y0] + list(yseries))
