"""Closed-form higher derivatives of smooth activation functions.

The Faa di Bruno contraction (core/jet.py) needs all outer coefficients
``F_m = sigma^(m)(a)/m!`` for ``m = 0..n`` at the primal activations ``a``.
Computing these with nested autodiff would re-introduce the exponential blow-up
the paper removes, so every supported activation provides them in closed form:

* ``tanh``:    sigma' = 1 - u^2 with u = tanh(a).  Every derivative is a
               polynomial in u via the recurrence P_{m+1}(u) = P_m'(u)(1-u^2).
               One transcendental + Horner chains -- VPU friendly on TPU.
* ``sigmoid``: same trick with s' = s(1-s).
* ``softplus``:softplus' = sigmoid, so order-m derivatives reuse the sigmoid
               polynomials shifted by one.
* ``sin``:     sigma^(m)(a) = sin(a + m*pi/2).
* ``exp``:     sigma^(m) = exp.
* ``identity``/``silu``/``gelu``: silu and (tanh-)gelu are *compositions* of
               the atoms above with products; they go through the jet algebra
               (mul + tanh/sigmoid jets) rather than a direct table.

Polynomial coefficient tables are exact integers computed once (lru_cache);
evaluation is Horner in the activation value.  The same tables are shared by
the Pallas kernels (kernels/bell_tables.py re-exports them).
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import Callable, Dict, Tuple

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Exact integer polynomial tables
# ---------------------------------------------------------------------------

def _poly_mul(a: Tuple[int, ...], b: Tuple[int, ...]) -> Tuple[int, ...]:
    out = [0] * (len(a) + len(b) - 1)
    for i, ai in enumerate(a):
        for j, bj in enumerate(b):
            out[i + j] += ai * bj
    return tuple(out)


def _poly_diff(a: Tuple[int, ...]) -> Tuple[int, ...]:
    return tuple(i * ai for i, ai in enumerate(a))[1:] or (0,)


@lru_cache(maxsize=None)
def tanh_derivative_polys(n: int) -> Tuple[Tuple[int, ...], ...]:
    """P_m with tanh^(m)(a) = P_m(tanh(a)), for m = 0..n.  P_0 = u."""
    polys = [(0, 1)]  # P_0(u) = u
    dchain = (1, 0, -1)  # u' = 1 - u^2
    for _ in range(n):
        polys.append(_poly_mul(_poly_diff(polys[-1]), dchain))
    return tuple(polys)


@lru_cache(maxsize=None)
def sigmoid_derivative_polys(n: int) -> Tuple[Tuple[int, ...], ...]:
    """Q_m with sigmoid^(m)(a) = Q_m(sigmoid(a)), for m = 0..n.  Q_0 = s."""
    polys = [(0, 1)]  # Q_0(s) = s
    dchain = (0, 1, -1)  # s' = s - s^2
    for _ in range(n):
        polys.append(_poly_mul(_poly_diff(polys[-1]), dchain))
    return tuple(polys)


def poly_table_f32(polys: Tuple[Tuple[int, ...], ...]) -> np.ndarray:
    """Pack ragged integer polys into a dense (m+1, deg+1) float array (low->high)."""
    deg = max(len(p) for p in polys)
    out = np.zeros((len(polys), deg), dtype=np.float64)
    for i, p in enumerate(polys):
        out[i, : len(p)] = p
    return out


def _horner(table_row: np.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Evaluate sum_i c_i u^i with Horner; table_row is low->high order."""
    acc = jnp.full_like(u, float(table_row[-1]))
    for c in table_row[-2::-1]:
        acc = acc * u + float(c)
    return acc


# ---------------------------------------------------------------------------
# Taylor-coefficient stacks F_m = sigma^(m)(a)/m!
# ---------------------------------------------------------------------------

def tanh_taylor_stack(a: jnp.ndarray, n: int) -> jnp.ndarray:
    """(n+1, *a.shape) stack of tanh^(m)(a)/m!."""
    u = jnp.tanh(a)
    table = poly_table_f32(tanh_derivative_polys(n))
    rows = [u]
    for m in range(1, n + 1):
        rows.append(_horner(table[m], u) * (1.0 / math.factorial(m)))
    return jnp.stack(rows)


def sigmoid_taylor_stack(a: jnp.ndarray, n: int) -> jnp.ndarray:
    s = jax_sigmoid(a)
    table = poly_table_f32(sigmoid_derivative_polys(n))
    rows = [s]
    for m in range(1, n + 1):
        rows.append(_horner(table[m], s) * (1.0 / math.factorial(m)))
    return jnp.stack(rows)


def softplus_taylor_stack(a: jnp.ndarray, n: int) -> jnp.ndarray:
    """softplus^(0) = log1p(exp a); higher orders are sigmoid derivatives shifted by one."""
    rows = [jnp.logaddexp(a, 0.0)]
    if n >= 1:
        s = jax_sigmoid(a)
        table = poly_table_f32(sigmoid_derivative_polys(max(n - 1, 0)))
        for m in range(1, n + 1):
            rows.append(_horner(table[m - 1], s) * (1.0 / math.factorial(m)))
    return jnp.stack(rows)


def sin_taylor_stack(a: jnp.ndarray, n: int) -> jnp.ndarray:
    rows = []
    for m in range(n + 1):
        phase = m % 4
        val = [jnp.sin, jnp.cos, lambda x: -jnp.sin(x), lambda x: -jnp.cos(x)][phase](a)
        rows.append(val * (1.0 / math.factorial(m)))
    return jnp.stack(rows)


def exp_taylor_stack(a: jnp.ndarray, n: int) -> jnp.ndarray:
    e = jnp.exp(a)
    return jnp.stack([e * (1.0 / math.factorial(m)) for m in range(n + 1)])


def jax_sigmoid(a: jnp.ndarray) -> jnp.ndarray:
    return 0.5 * (jnp.tanh(0.5 * a) + 1.0)


# registry: name -> callable(a, n) -> (n+1, *shape) Taylor stack
TAYLOR_STACKS: Dict[str, Callable[[jnp.ndarray, int], jnp.ndarray]] = {
    "tanh": tanh_taylor_stack,
    "sigmoid": sigmoid_taylor_stack,
    "softplus": softplus_taylor_stack,
    "sin": sin_taylor_stack,
    "exp": exp_taylor_stack,
}

# tanh-approximation GELU constants, shared with the jet-side composition
# (repro.core.jet.gelu) so primal and jet can never drift apart
GELU_TANH_C = math.sqrt(2.0 / math.pi)
GELU_TANH_CUBIC = 0.044715

# plain primal evaluation (for order-0 fast paths).  The composite names
# (silu / gelu / relu / identity) have no Taylor table -- their jets go
# through repro.core.jet.activation's algebraic definitions instead.
PRIMALS: Dict[str, Callable[[jnp.ndarray], jnp.ndarray]] = {
    "tanh": jnp.tanh,
    "sigmoid": jax_sigmoid,
    "softplus": lambda a: jnp.logaddexp(a, 0.0),
    "sin": jnp.sin,
    "exp": jnp.exp,
    "silu": lambda a: a * jax_sigmoid(a),
    "gelu": lambda a: 0.5 * a * (1.0 + jnp.tanh(
        GELU_TANH_C * (a + GELU_TANH_CUBIC * a ** 3))),
    "relu": lambda a: jnp.maximum(a, 0.0),
    "identity": lambda a: a,
}
