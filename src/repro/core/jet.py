"""Taylor-jet algebra: the n-TangentProp derivative stack and its arithmetic.

A ``Jet`` holds scaled Taylor coefficients ``c_k = (1/k!) d^k x(t)/dt^k`` of a
quantity along a 1-parameter input curve ``t -> f(x0 + t v)``, stacked on a
leading axis: ``coeffs[k]`` has the shape of the underlying tensor.  The
scaled normalization (vs raw derivatives) makes every rule below a clean
power-series identity with small integer constants (DESIGN.md section 2):

* linear maps apply coefficient-wise (bias touches only ``c_0``);
* products are Cauchy convolutions ``(AB)_k = sum_{i+j=k} A_i B_j`` --
  this covers matmul/einsum contractions between two jets (attention!);
* smooth scalar functions compose via the Taylor-normalized Faa di Bruno
  contraction (core/partitions.py) with closed-form outer coefficients
  (core/activations.py);
* ``exp/log/div/pow`` use the classical power-series recurrences, which are
  cheaper (O(n^2)) than the generic partition sum (O(n p(n))).

Everything is shape-polymorphic and jit/scan/pjit friendly: a Jet is a pytree
whose single leaf is the ``(order+1, *shape)`` stack, so it shards exactly
like a batch-expanded activation tensor.
"""

from __future__ import annotations

import math
from typing import Callable, Sequence, Union

import jax
import jax.numpy as jnp

from .activations import GELU_TANH_C, GELU_TANH_CUBIC, TAYLOR_STACKS
from .partitions import faa_di_bruno_table


@jax.tree_util.register_pytree_node_class
class Jet:
    """Stack of scaled Taylor coefficients c_0..c_n on a leading axis."""

    __slots__ = ("coeffs",)

    def __init__(self, coeffs: jnp.ndarray):
        self.coeffs = coeffs

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.coeffs,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    # -- basic accessors ----------------------------------------------------
    @property
    def order(self) -> int:
        return self.coeffs.shape[0] - 1

    @property
    def primal(self) -> jnp.ndarray:
        return self.coeffs[0]

    @property
    def shape(self):
        return self.coeffs.shape[1:]

    @property
    def dtype(self):
        return self.coeffs.dtype

    def __repr__(self):
        return f"Jet(order={self.order}, shape={self.shape}, dtype={self.dtype})"

    # -- operator sugar -------------------------------------------------------
    def __add__(self, other):
        return add(self, other)

    __radd__ = __add__

    def __sub__(self, other):
        return sub(self, other)

    def __rsub__(self, other):
        return sub(other, self)

    def __mul__(self, other):
        return mul(self, other)

    __rmul__ = __mul__

    def __truediv__(self, other):
        return div(self, other)

    def __neg__(self):
        return Jet(-self.coeffs)


JetLike = Union[Jet, jnp.ndarray, float, int]


# ---------------------------------------------------------------------------
# construction / extraction
# ---------------------------------------------------------------------------

def seed(x: jnp.ndarray, v: jnp.ndarray | None, order: int) -> Jet:
    """Jet of the curve t -> x + t v  (c_0 = x, c_1 = v, higher = 0)."""
    if v is None:
        v = jnp.ones_like(x)
    zeros = [jnp.zeros_like(x) for _ in range(order - 1)]
    return Jet(jnp.stack([x, v.astype(x.dtype)] + zeros))


def const(x: JetLike, order: int, like: Jet | None = None) -> Jet:
    """Constant-in-t jet (only c_0 populated)."""
    if isinstance(x, Jet):
        return x
    x = jnp.asarray(x, dtype=None if like is None else like.dtype)
    return Jet(jnp.concatenate([x[None], jnp.zeros((order,) + x.shape, x.dtype)]))


def derivatives(j: Jet) -> jnp.ndarray:
    """Raw derivatives d^k f/dt^k = k! * c_k, stacked (order+1, *shape)."""
    facts = jnp.asarray([math.factorial(k) for k in range(j.order + 1)], j.dtype)
    return j.coeffs * facts.reshape((-1,) + (1,) * len(j.shape))


def from_derivatives(d: jnp.ndarray) -> Jet:
    """Inverse of :func:`derivatives`."""
    n = d.shape[0] - 1
    inv = jnp.asarray([1.0 / math.factorial(k) for k in range(n + 1)], d.dtype)
    return Jet(d * inv.reshape((-1,) + (1,) * (d.ndim - 1)))


def _align(a: Jet, b: Jet) -> tuple[Jet, Jet]:
    """Insert singleton dims after the coefficient axis so the *underlying*
    shapes broadcast by trailing-dim rules (coeff axis stays leading)."""
    na, nb = len(a.shape), len(b.shape)
    if na < nb:
        a = Jet(a.coeffs.reshape(a.coeffs.shape[:1] + (1,) * (nb - na) + a.shape))
    elif nb < na:
        b = Jet(b.coeffs.reshape(b.coeffs.shape[:1] + (1,) * (na - nb) + b.shape))
    return a, b


def _promote(a: JetLike, b: JetLike) -> tuple[Jet, Jet]:
    if isinstance(a, Jet) and isinstance(b, Jet):
        if a.order != b.order:
            raise ValueError(f"jet order mismatch: {a.order} vs {b.order}")
        return _align(a, b)
    if isinstance(a, Jet):
        return _align(a, const(b, a.order, like=a))
    if isinstance(b, Jet):
        return _align(const(a, b.order, like=b), b)
    raise TypeError("at least one operand must be a Jet")


# ---------------------------------------------------------------------------
# linear operations (coefficient-wise)
# ---------------------------------------------------------------------------

def jmap(fn: Callable[..., jnp.ndarray], *jets: Jet) -> Jet:
    """Apply a *linear* array function to each coefficient (reshape, reduce-sum,
    transpose, pad, slice, concat of jets, multiplication by a constant...)."""
    n = jets[0].order
    rows = [fn(*(j.coeffs[k] for j in jets)) for k in range(n + 1)]
    return Jet(jnp.stack(rows))


def add(a: JetLike, b: JetLike) -> Jet:
    a, b = _promote(a, b)
    return Jet(a.coeffs + b.coeffs)


def sub(a: JetLike, b: JetLike) -> Jet:
    a, b = _promote(a, b)
    return Jet(a.coeffs - b.coeffs)


def scale(a: Jet, s) -> Jet:
    """Multiply by a t-constant scalar/array (broadcasts like arrays)."""
    return Jet(a.coeffs * s)


def linear(a: Jet, w: jnp.ndarray, b: jnp.ndarray | None = None,
           eq: str = "...i,ij->...j") -> Jet:
    """Dense layer on a jet: W acts on every coefficient, bias only on c_0.

    ``eq`` must open with an ellipsis on the jet operand: the coefficient
    axis (and any leading batch/token axes) folds into the ``...`` so the
    whole stack contracts in ONE einsum instead of per-coefficient calls."""
    if not eq.startswith("..."):
        raise ValueError(f"linear eq must start with '...' so the "
                         f"coefficient axis can ride it, got {eq!r}")
    out = jnp.einsum(eq, a.coeffs, w)
    if b is not None:
        out = out.at[0].add(b)
    return Jet(out)


def reduce_sum(a: Jet, axis, keepdims: bool = False) -> Jet:
    return jmap(lambda c: jnp.sum(c, axis=axis, keepdims=keepdims), a)


def reduce_mean(a: Jet, axis, keepdims: bool = False) -> Jet:
    return jmap(lambda c: jnp.mean(c, axis=axis, keepdims=keepdims), a)


def where(mask: jnp.ndarray, a: JetLike, b: JetLike) -> Jet:
    """Select with a t-constant predicate (exact a.e.; mask must not depend on t)."""
    a, b = _promote(a, b)
    return jmap(lambda x, y: jnp.where(mask, x, y), a, b)


# ---------------------------------------------------------------------------
# bilinear operations (Cauchy convolution over the coefficient axis)
# ---------------------------------------------------------------------------

def _cauchy(a: Jet, b: Jet, combine: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]) -> Jet:
    n = a.order
    rows = []
    for k in range(n + 1):
        acc = combine(a.coeffs[0], b.coeffs[k])
        for i in range(1, k + 1):
            acc = acc + combine(a.coeffs[i], b.coeffs[k - i])
        rows.append(acc)
    return Jet(jnp.stack(rows))


def mul(a: JetLike, b: JetLike) -> Jet:
    a, b = _promote(a, b)
    return _cauchy(a, b, jnp.multiply)


def einsum(eq: str, a: JetLike, b: JetLike) -> Jet:
    """Jet-valued contraction: out_k = sum_{i+j=k} einsum(eq, a_i, b_j).

    If one operand is t-constant the convolution degenerates to a per-
    coefficient einsum (no extra FLOPs vs the primal op times (n+1)).
    NOTE: no broadcast alignment here -- einsum subscripts fix the ranks."""
    if isinstance(a, Jet) and not isinstance(b, Jet):
        return jmap(lambda c: jnp.einsum(eq, c, b), a)
    if isinstance(b, Jet) and not isinstance(a, Jet):
        return jmap(lambda c: jnp.einsum(eq, a, c), b)
    if a.order != b.order:
        raise ValueError(f"jet order mismatch: {a.order} vs {b.order}")
    return _cauchy(a, b, lambda x, y: jnp.einsum(eq, x, y))


# ---------------------------------------------------------------------------
# power-series recurrences
# ---------------------------------------------------------------------------

def exp(a: Jet) -> Jet:
    """e_0 = exp(a_0);  e_k = (1/k) sum_{j=1..k} j a_j e_{k-j}."""
    n = a.order
    rows = [jnp.exp(a.coeffs[0])]
    for k in range(1, n + 1):
        acc = a.coeffs[k] * rows[0] * k  # j = k term
        for j in range(1, k):
            acc = acc + j * a.coeffs[j] * rows[k - j]
        rows.append(acc / k)
    return Jet(jnp.stack(rows))


def log(a: Jet) -> Jet:
    """l_0 = log a_0;  l_k = (a_k - (1/k) sum_{j=1..k-1} j l_j a_{k-j}) / a_0."""
    n = a.order
    inv0 = 1.0 / a.coeffs[0]
    rows = [jnp.log(a.coeffs[0])]
    for k in range(1, n + 1):
        acc = a.coeffs[k]
        for j in range(1, k):
            acc = acc - (j / k) * rows[j] * a.coeffs[k - j]
        rows.append(acc * inv0)
    return Jet(jnp.stack(rows))


def div(a: JetLike, b: JetLike) -> Jet:
    """c_k = (a_k - sum_{j=1..k} b_j c_{k-j}) / b_0."""
    a, b = _promote(a, b)
    inv0 = 1.0 / b.coeffs[0]
    rows = [a.coeffs[0] * inv0]
    for k in range(1, a.order + 1):
        acc = a.coeffs[k]
        for j in range(1, k + 1):
            acc = acc - b.coeffs[j] * rows[k - j]
        rows.append(acc * inv0)
    return Jet(jnp.stack(rows))


def powr(a: Jet, r: float) -> Jet:
    """a^r (real r) via the J.C.P. Miller recurrence:
    c_k = (1/(k a_0)) sum_{j=1..k} ((r+1) j - k) a_j c_{k-j}."""
    n = a.order
    inv0 = 1.0 / a.coeffs[0]
    rows = [jnp.power(a.coeffs[0], r)]
    for k in range(1, n + 1):
        acc = ((r + 1) * 1 - k) * a.coeffs[1] * rows[k - 1]
        for j in range(2, k + 1):
            acc = acc + ((r + 1) * j - k) * a.coeffs[j] * rows[k - j]
        rows.append(acc * inv0 / k)
    return Jet(jnp.stack(rows))


def sqrt(a: Jet) -> Jet:
    return powr(a, 0.5)


def rsqrt(a: Jet) -> Jet:
    return powr(a, -0.5)


# ---------------------------------------------------------------------------
# smooth scalar composition (Faa di Bruno)
# ---------------------------------------------------------------------------

def compose(a: Jet, name: str) -> Jet:
    """sigma(a) for a registered smooth activation, via the Taylor-normalized
    Faa di Bruno contraction with closed-form outer coefficients."""
    n = a.order
    fstack = TAYLOR_STACKS[name](a.coeffs[0], n)  # (n+1, *shape)
    rows = [fstack[0]]
    for k in range(1, n + 1):
        acc = None
        for term in faa_di_bruno_table(k):
            prod = fstack[term.order] * float(term.coef)
            for j, e in term.powers:
                cj = a.coeffs[j]
                for _ in range(e):
                    prod = prod * cj
            acc = prod if acc is None else acc + prod
        rows.append(acc)
    return Jet(jnp.stack(rows))


def tanh(a: Jet) -> Jet:
    return compose(a, "tanh")


def sigmoid(a: Jet) -> Jet:
    return compose(a, "sigmoid")


def sin(a: Jet) -> Jet:
    return compose(a, "sin")


def softplus(a: Jet) -> Jet:
    return compose(a, "softplus")


def silu(a: Jet) -> Jet:
    return mul(a, sigmoid(a))


def gelu(a: Jet) -> Jet:
    """tanh-approximation GELU as a pure jet composition (poly + tanh + mul);
    constants shared with PRIMALS['gelu'] via core.activations."""
    a3 = mul(mul(a, a), a)
    inner = scale(add(a, scale(a3, GELU_TANH_CUBIC)), GELU_TANH_C)
    return scale(mul(a, add(tanh(inner), 1.0)), 0.5)


def relu(a: Jet) -> Jet:
    """Piecewise-linear: exact wherever a_0 != 0 (jets vanish on the off side)."""
    return where(a.coeffs[0] > 0, a, scale(a, 0.0))


def identity(a: Jet) -> Jet:
    return a


_COMPOSITE_ACTS: dict[str, Callable[[Jet], Jet]] = {
    "silu": silu, "gelu": gelu, "relu": relu, "identity": identity,
}


def activation(a: Jet, name: str) -> Jet:
    """Named activation on a jet: table-backed names go through the Faa di
    Bruno contraction (:func:`compose`); composite ones (silu, gelu, relu,
    identity) through their jet-algebra definitions.  The single dispatch
    point for :class:`repro.core.modules.Dense`/``Activation`` leaves."""
    if name in TAYLOR_STACKS:
        return compose(a, name)
    if name in _COMPOSITE_ACTS:
        return _COMPOSITE_ACTS[name](a)
    raise KeyError(f"unknown activation {name!r}; known: "
                   f"{sorted(set(TAYLOR_STACKS) | set(_COMPOSITE_ACTS))}")


# ---------------------------------------------------------------------------
# softmax & norms (built from the primitives; used by attention jets)
# ---------------------------------------------------------------------------

# Finite stand-in for -inf at masked softmax positions: exp underflows to
# exactly 0 (killing the whole e-jet there by the exp recurrence), while
# arithmetic on it stays NaN-free -- a true -inf would produce inf - inf
# under the shift and 0 * inf in the recurrences.  Shared with the Pallas
# flash kernel (kernels/jet_attention.py).
MASK_NEG = -1e30


def softmax(a: Jet, axis: int = -1, mask: jnp.ndarray | None = None) -> Jet:
    """Softmax jet over ``axis``; ``mask`` is an optional t-constant boolean
    keep-matrix (True = attend, broadcastable against the coefficients).
    Masked positions are replaced by the constant jet ``MASK_NEG`` *before*
    the exp recurrence, so their probability jets vanish identically at
    every order and no inf/NaN enters even under differentiation.  A row
    that keeps NO position degrades gracefully instead of producing NaN:
    the whole row becomes the constant ``MASK_NEG`` jet, the shift cancels
    it exactly, and the result is the uniform distribution with zero
    higher-order coefficients (pinned by tests/test_jet.py)."""
    if mask is not None:
        a = where(mask, a, MASK_NEG)
    shift = jax.lax.stop_gradient(jnp.max(a.coeffs[0], axis=axis, keepdims=True))
    e = exp(sub(a, const(shift, a.order, like=a)))
    s = reduce_sum(e, axis=axis, keepdims=True)
    return div(e, s)


def rms_norm(x: Jet, gamma: jnp.ndarray, eps: float = 1e-6,
             axis: int = -1, offset: float = 0.0) -> Jet:
    ms = reduce_mean(mul(x, x), axis=axis, keepdims=True)
    inv = rsqrt(add(ms, eps))
    return scale(mul(x, inv), (offset + gamma))


def layer_norm(x: Jet, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5,
               axis: int = -1) -> Jet:
    mu = reduce_mean(x, axis=axis, keepdims=True)
    xc = sub(x, mu)
    var = reduce_mean(mul(xc, xc), axis=axis, keepdims=True)
    y = mul(xc, rsqrt(add(var, eps)))
    y = scale(y, gamma)
    return add(y, const(beta, x.order, like=x))
