"""n-TangentProp: the paper's algorithm (Alg. 1) for dense feed-forward nets.

This is the faithful reproduction of the paper's contribution: compute
``f(x), f'(x), ..., f^(n)(x)`` w.r.t. the *network inputs* in a single
forward pass.  Linear layers act coefficient-wise on the jet; activations go
through the Faa di Bruno contraction.  Cost is ``O(n p(n) M)`` time and
``O(n M)`` memory -- quasilinear in the model size M, versus ``O(M^n)`` for
nested autodiff.

Two execution paths:
* ``impl='jnp'``    -- pure jax.numpy (reference; used by tests/oracles)
* ``impl='pallas'`` -- fused Pallas kernels (kernels/jet_dense.py): one VMEM
                       round-trip per layer tile, MXU for the stacked GEMM.

Gradients w.r.t. parameters flow through either path with ordinary
``jax.grad`` -- that single reverse sweep over the jet forward is exactly the
paper's "backward pass" and stays O(n p(n) M).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from . import jet as J


class MLPParams(NamedTuple):
    """Stacked weights for a uniform-width MLP (paper's architecture)."""

    w_in: jnp.ndarray    # (d_in, width)
    b_in: jnp.ndarray    # (width,)
    w_hidden: jnp.ndarray  # (depth-1, width, width) -- scanned
    b_hidden: jnp.ndarray  # (depth-1, width)
    w_out: jnp.ndarray   # (width, d_out)
    b_out: jnp.ndarray   # (d_out,)


def xavier_uniform(key: jax.Array, fan_in: int, fan_out: int,
                   dtype=jnp.float32) -> jnp.ndarray:
    """Xavier-uniform weight init matching the paper's PyTorch defaults
    (shared by every architecture in core/network.py)."""
    lim = jnp.sqrt(6.0 / (fan_in + fan_out)).astype(dtype)
    return jax.random.uniform(key, (fan_in, fan_out), dtype, -lim, lim)


def init_mlp(key: jax.Array, d_in: int, width: int, depth: int, d_out: int,
             dtype=jnp.float32) -> MLPParams:
    ks = jax.random.split(key, depth + 1)

    def xavier(k, fan_in, fan_out):
        return xavier_uniform(k, fan_in, fan_out, dtype)

    w_in = xavier(ks[0], d_in, width)
    wh = jnp.stack([xavier(ks[i + 1], width, width) for i in range(depth - 1)]) \
        if depth > 1 else jnp.zeros((0, width, width), dtype)
    w_out = xavier(ks[depth], width, d_out)
    return MLPParams(
        w_in=w_in, b_in=jnp.zeros((width,), dtype),
        w_hidden=wh, b_hidden=jnp.zeros((max(depth - 1, 0), width), dtype),
        w_out=w_out, b_out=jnp.zeros((d_out,), dtype),
    )


def num_params(p: MLPParams) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(p))


def mlp_apply(params: MLPParams, x: jnp.ndarray, activation: str = "tanh",
              unroll: bool = False) -> jnp.ndarray:
    """Plain forward pass (no derivatives).  ``unroll=True`` avoids lax.scan
    (needed by jax.experimental.jet, which has no scan rule)."""
    from .activations import PRIMALS
    act = PRIMALS[activation]
    h = act(x @ params.w_in + params.b_in)

    if unroll:
        for i in range(params.w_hidden.shape[0]):
            h = act(h @ params.w_hidden[i] + params.b_hidden[i])
        return h @ params.w_out + params.b_out

    def body(h, wb):
        w, b = wb
        return act(h @ w + b), None

    if params.w_hidden.shape[0]:
        h, _ = jax.lax.scan(body, h, (params.w_hidden, params.b_hidden))
    return h @ params.w_out + params.b_out


# ---------------------------------------------------------------------------
# the n-TangentProp forward pass
# ---------------------------------------------------------------------------

def ntp_jet(params: MLPParams, jet: J.Jet, activation: str = "tanh",
            impl: str = "jnp") -> J.Jet:
    """Push an input jet through the dense stack (the body of Algorithm 1).

    This is the ``Network.jet_apply`` of the paper's architecture; it is
    split out from :func:`ntp_forward` so :class:`repro.core.network.DenseMLP`
    can run arbitrary pre-seeded jets through the same code path.
    """
    if impl == "pallas":
        from repro.kernels import ops as kops
        coeffs = kops.jet_dense(jet.coeffs, params.w_in, params.b_in, activation)

        def body(coeffs, wb):
            w, b = wb
            return kops.jet_dense(coeffs, w, b, activation), None

        if params.w_hidden.shape[0]:
            coeffs, _ = jax.lax.scan(body, coeffs, (params.w_hidden, params.b_hidden))
        jet = J.Jet(coeffs)
        return J.linear(jet, params.w_out, params.b_out)

    # reference path: jet algebra, scanned over the hidden stack
    jet = J.compose(J.linear(jet, params.w_in, params.b_in), activation)

    def body(coeffs, wb):
        w, b = wb
        j = J.compose(J.linear(J.Jet(coeffs), w, b), activation)
        return j.coeffs, None

    if params.w_hidden.shape[0]:
        coeffs, _ = jax.lax.scan(body, jet.coeffs, (params.w_hidden, params.b_hidden))
        jet = J.Jet(coeffs)
    return J.linear(jet, params.w_out, params.b_out)


def ntp_forward(params: MLPParams, x: jnp.ndarray, order: int,
                tangent: jnp.ndarray | None = None, activation: str = "tanh",
                impl: str = "jnp") -> J.Jet:
    """Jet of the network output along the input curve ``x + t v``.

    ``x``: (batch, d_in).  ``tangent`` defaults to ones (the paper's 1-D PINN
    seeding ``y_1 = L_1(1) - b_1``).  Returns a Jet of (batch, d_out).
    """
    if order == 0:
        y = mlp_apply(params, x, activation)
        return J.Jet(y[None])
    return ntp_jet(params, J.seed(x, tangent, order), activation, impl)


def ntp_derivatives(params: MLPParams, x: jnp.ndarray, order: int,
                    tangent: jnp.ndarray | None = None, activation: str = "tanh",
                    impl: str = "jnp") -> jnp.ndarray:
    """Raw derivatives (order+1, batch, d_out): d^k/dt^k f(x + t v) at t=0."""
    return J.derivatives(ntp_forward(params, x, order, tangent, activation, impl))


# ---------------------------------------------------------------------------
# multi-directional jets: full nabla^k for small input dimension d
#
# The direction folding and polarization algebra are engine- and network-
# generic; they live in core/engines.py.  These wrappers keep the seed
# MLPParams surface (and its callers/tests) working verbatim.
# ---------------------------------------------------------------------------

def _dense_view(params: MLPParams, activation: str, impl: str):
    from .engines import NTPEngine
    from .network import DenseMLP
    return DenseMLP.from_params(params, activation), NTPEngine(impl)


def ntp_grid(params: MLPParams, x: jnp.ndarray, order: int, activation: str = "tanh",
             impl: str = "jnp") -> jnp.ndarray:
    """Pure n-th derivatives along each coordinate axis: (d_in, order+1, batch, d_out).

    PINN losses for 1-D/2-D problems only need pure (non-mixed) directional
    derivatives per axis; mixed partials are recovered by polarization of
    directional jets -- see :func:`cross`.
    """
    net, engine = _dense_view(params, activation, impl)
    return engine.grid(net, params, x, order)


def cross(params: MLPParams, x: jnp.ndarray, axes: Sequence[int],
          activation: str = "tanh", impl: str = "jnp") -> jnp.ndarray:
    """Mixed partial ``d^m f / dx_{axes[0]} ... dx_{axes[m-1]}`` at each point,
    shape (batch, d_out), via the polarization identity

        D_{v_1 ... v_m} f = 1/(2^m m!) sum_{eps in {+-1}^m}
                            (prod_k eps_k) D^m_{sum_k eps_k v_k} f

    with ``v_k = e_{axes[k]}``.  Repeated axes are allowed (``axes=(0, 0, 1)``
    gives u_xxy), so together with :func:`ntp_grid` this spans the full
    nabla^m tensor from 2^m directional jets -- still one n-TangentProp batch,
    never a nested-autodiff graph.
    """
    net, engine = _dense_view(params, activation, impl)
    return engine.cross(net, params, x, axes)
