"""Integer partitions and Faa di Bruno coefficient tables.

n-TangentProp propagates *scaled Taylor coefficients* ``c_k = f^(k)/k!``
instead of raw derivatives (DESIGN.md section 2).  In that normalization the
composition rule for ``h = f(g(t))`` with inner coefficients ``u_j`` (j>=1)
and outer coefficients ``F_m = f^(m)(g_0)/m!`` reads

    h_k = sum_{p in P(k)}  (|p|! / prod_j p_j!) * F_{|p|} * prod_j u_j^{p_j}

where ``P(k)`` is the set of integer partitions of ``k`` written as exponent
vectors ``p = (p_1, .., p_k)`` with ``sum_j j*p_j = k`` and ``|p| = sum_j p_j``.
The multinomial coefficients are small exact integers -- contrast the raw
derivative normalization whose Bell-polynomial constants grow like ``k!``.

Everything here is pure Python / exact integer arithmetic, executed once at
trace time and cached.  The tables are tiny: ``p(12) = 77`` partitions.
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import NamedTuple, Sequence, Tuple


class FdBTerm(NamedTuple):
    """One partition term of the Taylor-normalized Faa di Bruno sum."""

    coef: int                         # |p|! / prod_j p_j!
    order: int                        # |p| = which outer coefficient F_m to use
    powers: Tuple[Tuple[int, int], ...]  # ((j, p_j), ...) for p_j != 0


@lru_cache(maxsize=None)
def partitions(n: int) -> Tuple[Tuple[int, ...], ...]:
    """All integer partitions of ``n`` as descending tuples, e.g. 4 -> (4),(3,1),(2,2),(2,1,1),(1,1,1,1)."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if n == 0:
        return ((),)

    out = []

    def rec(remaining: int, maxpart: int, prefix: Tuple[int, ...]) -> None:
        if remaining == 0:
            out.append(prefix)
            return
        for part in range(min(maxpart, remaining), 0, -1):
            rec(remaining - part, part, prefix + (part,))

    rec(n, n, ())
    return tuple(out)


def partition_count(n: int) -> int:
    """The partition function p(n) = |P(n)|."""
    return len(partitions(n))


@lru_cache(maxsize=None)
def faa_di_bruno_table(k: int) -> Tuple[FdBTerm, ...]:
    """Taylor-normalized Faa di Bruno terms for output order ``k >= 1``."""
    if k < 1:
        raise ValueError(f"order must be >= 1, got {k}")
    terms = []
    for part in partitions(k):
        # exponent representation: p_j = multiplicity of j in the partition
        exps = {}
        for j in part:
            exps[j] = exps.get(j, 0) + 1
        m = len(part)  # |p|
        denom = 1
        for e in exps.values():
            denom *= math.factorial(e)
        coef = math.factorial(m) // denom
        terms.append(FdBTerm(coef=coef, order=m, powers=tuple(sorted(exps.items()))))
    # deterministic ordering: by |p| then lexicographic powers
    terms.sort(key=lambda t: (t.order, t.powers))
    return tuple(terms)


@lru_cache(maxsize=None)
def bell_number(n: int) -> int:
    """Bell number B_n = number of set partitions; used as a property-test oracle.

    Identity used by tests: the *raw-derivative* Bell coefficients sum to B_n.
    In our Taylor normalization the equivalent identity is

        sum_{p in P(n)} coef(p) * n! / prod_j (j!)^{p_j} / |p|!  * |p|!  ... (reduces back)

    We instead verify via the classical recurrence below.
    """
    if n == 0:
        return 1
    return sum(math.comb(n - 1, j) * bell_number(j) for j in range(n))


def raw_bell_coefficient(part: Sequence[int], n: int) -> int:
    """Coefficient of a partition in the classical (raw-derivative) Faa di Bruno formula.

    For raw derivatives: C_p = n! / ( prod_j (j!)^{p_j} * p_j! ).  Summing
    C_p over all partitions of n yields the Bell number B_n -- a property the
    tests exploit to validate the partition generator end-to-end.
    """
    exps = {}
    for j in part:
        exps[j] = exps.get(j, 0) + 1
    denom = 1
    for j, e in exps.items():
        denom *= math.factorial(j) ** e * math.factorial(e)
    return math.factorial(n) // denom


@lru_cache(maxsize=None)
def total_fdb_terms(n: int) -> int:
    """sum_{k<=n} p(k): total contraction terms a full order-n propagation runs."""
    return sum(partition_count(k) for k in range(1, n + 1))
