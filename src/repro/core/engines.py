"""Derivative engines: one uniform surface over every way this repo computes
higher-order input derivatives of a network.

An engine answers three questions about any :class:`repro.core.network.Network`:

* ``derivs(net, params, x, order, tangent=None)`` -- raw directional
  derivatives ``d^k/dt^k f(x + t v)`` at t=0, stacked (order+1, N, d_out);
* ``grid(net, params, x, order)`` -- pure derivatives along every coordinate
  axis, (d_in, order+1, N, d_out), with the direction axis folded into the
  batch so the whole grid is ONE forward (a single Pallas launch per layer);
* ``cross(net, params, x, axes)`` -- the mixed partial
  ``d^m f / dx_{a_1}..dx_{a_m}``, (N, d_out), by polarization of 2^m
  directional derivatives (never a nested-autodiff graph).

``grid`` and ``cross`` are engine-generic: they are assembled from ``derivs``
here in the base class, so a new engine implements one method and inherits
the whole surface.  Shipped engines:

=====================  =====================================================
``NTPEngine(impl)``    the paper's quasilinear jet forward (Algorithm 1);
                       ``impl="jnp"`` reference or ``impl="pallas"`` fused
                       kernels -- O(n p(n) M) time, O(n M) memory
``AutodiffEngine()``   nested autodiff towers, the O(M^n) baseline the paper
                       benchmarks against (reverse-mode for scalar outputs,
                       forward-over-forward for vector outputs)
``JaxJetEngine()``     ``jax.experimental.jet`` -- JAX's independent
                       Taylor-mode implementation, used as a correctness
                       oracle for ours
=====================  =====================================================

Configs address engines by spec string: ``Engine.from_spec("ntp/pallas")``,
``"ntp"``, ``"autodiff"``, ``"jet"``; instances pass through unchanged.
(The pre-redesign ``(engine="ntp", impl="pallas")`` keyword-pair shim was
removed after its scheduled one-release deprecation window.)

Spec strings have a typed, canonical identity: :class:`EngineSpec` parses
any accepted spelling (``"ntp"`` == ``"ntp/jnp"``, ``"jet"`` ==
``"jax-jet"`` == ``"jaxjet"``) to one frozen value whose ``str()`` is the
canonical form.  Everything keyed on an engine spec -- the serving layer's
``ExecutableKey.engine_spec``, benchmark row names -- goes through it, so
equivalent spellings share one compiled-executable cache entry and one
baseline row.

Every returned array carries a trailing component axis sized ``net.d_out``:
``derivs`` is (order+1, N, d_out), ``grid`` (d_in, order+1, N, d_out) and
``cross`` (N, d_out), for scalar fields and vector-valued PDE systems alike.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from . import jet as J
from .network import Network

# accepted alternate spellings -> canonical engine name
_SPEC_ALIASES = {"jax-jet": "jet", "jaxjet": "jet"}

# engine name -> implementation variants (None = no /impl suffix allowed)
_ENGINE_IMPLS = {"ntp": ("jnp", "pallas"), "autodiff": None, "jet": None}


@dataclass(frozen=True)
class EngineSpec:
    """Typed, canonical identity of an engine configuration.

    ``parse`` accepts every spelling ``from_spec`` does -- a spec string
    (``"ntp"``, ``"ntp/jnp"``, ``"ntp/pallas"``, ``"autodiff"``, ``"jet"``
    and its ``"jax-jet"``/``"jaxjet"`` aliases), an :class:`EngineSpec`, or
    a :class:`DerivativeEngine` instance -- and canonicalizes: ``"ntp"``
    and ``"ntp/jnp"`` are the SAME value (``impl`` is stored as ``"jnp"``,
    ``str()`` renders the short form).  ``str(EngineSpec.parse(s))`` is the
    canonical string every spec-keyed surface must use: the serving cache
    key (one compiled executable per distinct engine, not per spelling) and
    benchmark row names (one baseline row).  Round-trip law:
    ``EngineSpec.parse(str(spec)) == spec``.
    """

    name: str
    impl: str | None = None

    def __post_init__(self):
        impls = _ENGINE_IMPLS.get(self.name)
        if self.name not in _ENGINE_IMPLS:
            raise ValueError(f"unknown engine {self.name!r}; want one of "
                             f"{sorted(_ENGINE_IMPLS)}")
        if impls is None:
            if self.impl is not None:
                raise ValueError(f"engine {self.name!r} takes no /impl "
                                 f"suffix, got {self.impl!r}")
        else:
            impl = self.impl if self.impl is not None else impls[0]
            if impl not in impls:
                raise ValueError(f"unknown impl {impl!r} for engine "
                                 f"{self.name!r} (want one of {impls})")
            object.__setattr__(self, "impl", impl)

    @staticmethod
    def parse(spec: "str | EngineSpec | DerivativeEngine") -> "EngineSpec":
        if isinstance(spec, EngineSpec):
            return spec
        if isinstance(spec, DerivativeEngine):
            return EngineSpec.parse(spec.spec)
        name, _, impl = str(spec).strip().lower().partition("/")
        name = _SPEC_ALIASES.get(name, name)
        try:
            return EngineSpec(name, impl or None)
        except ValueError as e:
            raise ValueError(f"bad engine spec {spec!r}: {e}") from None

    def __str__(self) -> str:
        default = (_ENGINE_IMPLS.get(self.name) or (None,))[0]
        if self.impl is None or self.impl == default:
            return self.name
        return f"{self.name}/{self.impl}"

    def build(self) -> "DerivativeEngine":
        """Instantiate the engine this spec names."""
        if self.name == "ntp":
            return NTPEngine(self.impl)
        if self.name == "autodiff":
            return AutodiffEngine()
        return JaxJetEngine()


class DerivativeEngine:
    """Base class: implement ``derivs``, inherit ``grid``/``cross``."""

    def derivs(self, net: Network, params, x: jnp.ndarray, order: int,
               tangent: jnp.ndarray | None = None) -> jnp.ndarray:
        """Raw directional derivatives (order+1, N, d_out) along ``tangent``
        (defaults to ones, the seed convention for 1-D PINNs)."""
        raise NotImplementedError

    @property
    def spec(self) -> str:
        """The string this engine round-trips through :meth:`from_spec`."""
        raise NotImplementedError

    def _batched_directional(self, net: Network, params, x: jnp.ndarray,
                             dirs: jnp.ndarray, order: int) -> jnp.ndarray:
        """(n_dirs, order+1, N, d_out): derivatives along each row of ``dirs``,
        with the direction axis folded into the batch -- one large forward
        instead of a vmap over per-direction passes."""
        n_dirs, batch = dirs.shape[0], x.shape[0]
        xt = jnp.tile(x, (n_dirs, 1))
        vt = jnp.repeat(dirs, batch, axis=0)
        d = self.derivs(net, params, xt, order, vt)
        return jnp.moveaxis(d.reshape((order + 1, n_dirs, batch, -1)), 1, 0)

    def grid(self, net: Network, params, x: jnp.ndarray,
             order: int) -> jnp.ndarray:
        """Pure derivatives along every coordinate axis:
        (d_in, order+1, N, d_out)."""
        eye = jnp.eye(x.shape[-1], dtype=x.dtype)
        return self._batched_directional(net, params, x, eye, order)

    def cross(self, net: Network, params, x: jnp.ndarray,
              axes: Sequence[int]) -> jnp.ndarray:
        """Mixed partial ``d^m f / dx_{axes[0]} ... dx_{axes[m-1]}``, (N, d_out),
        via the polarization identity

            D_{v_1..v_m} f = 1/(2^m m!) sum_{eps in {+-1}^m}
                             (prod_k eps_k) D^m_{sum_k eps_k v_k} f

        with ``v_k = e_{axes[k]}``.  Repeated axes are allowed
        (``axes=(0, 0, 1)`` gives u_xxy)."""
        m, d = len(axes), x.shape[-1]
        if m == 0:
            raise ValueError("axes must name at least one differentiation axis")
        if any(a < 0 or a >= d for a in axes):
            raise ValueError(f"axes {tuple(axes)} out of range for d_in={d}")
        signs = jnp.asarray(list(itertools.product((1.0, -1.0), repeat=m)),
                            x.dtype)
        basis = jnp.eye(d, dtype=x.dtype)[jnp.asarray(axes)]   # (m, d)
        dirs = signs @ basis                                    # (2^m, d)
        derivs = self._batched_directional(net, params, x, dirs, m)
        coefs = jnp.prod(signs, axis=1)                         # (2^m,)
        top = jnp.tensordot(coefs, derivs[:, m], axes=1)        # (N, d_out)
        return top / (2.0 ** m * math.factorial(m))

    # -- spec parsing -------------------------------------------------------

    @staticmethod
    def from_spec(spec: "str | DerivativeEngine") -> "DerivativeEngine":
        """``"ntp"`` | ``"ntp/pallas"`` | ``"autodiff"`` | ``"jet"`` -> engine.
        Engine instances pass through unchanged; every string spelling goes
        through :meth:`EngineSpec.parse`, so aliases and the ``"ntp"`` ==
        ``"ntp/jnp"`` equivalence are handled in one place."""
        if isinstance(spec, DerivativeEngine):
            return spec
        return EngineSpec.parse(spec).build()

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


# ---------------------------------------------------------------------------
# n-TangentProp: the paper's algorithm through Network.jet_apply
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NTPEngine(DerivativeEngine):
    """Quasilinear Taylor-jet forward (paper Algorithm 1, generalized to any
    jet-traceable network)."""

    impl: str = "jnp"

    def __post_init__(self):
        if self.impl not in ("jnp", "pallas"):
            raise ValueError(f"unknown impl {self.impl!r} "
                             "(want 'jnp' or 'pallas')")

    @property
    def spec(self) -> str:
        return "ntp" if self.impl == "jnp" else f"ntp/{self.impl}"

    def derivs(self, net: Network, params, x: jnp.ndarray, order: int,
               tangent: jnp.ndarray | None = None) -> jnp.ndarray:
        if order == 0:
            return net.apply(params, x)[None]
        jet = net.jet_apply(params, J.seed(x, tangent, order), impl=self.impl)
        return J.derivatives(jet)


# ---------------------------------------------------------------------------
# nested autodiff: the O(M^n) baseline
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AutodiffEngine(DerivativeEngine):
    """Nested autodiff towers over ``net.apply`` -- the standard-PINN-practice
    baseline whose graph grows O(M^order).  Scalar outputs nest reverse-mode
    ``jax.grad`` (what PINN codebases actually do); vector outputs fall back
    to forward-over-forward ``jax.jacfwd`` towers."""

    @property
    def spec(self) -> str:
        return "autodiff"

    def derivs(self, net: Network, params, x: jnp.ndarray, order: int,
               tangent: jnp.ndarray | None = None) -> jnp.ndarray:
        if tangent is None:
            tangent = jnp.ones_like(x)
        scalar = net.d_out == 1

        def along(xi, vi):
            if scalar:
                def g(t):
                    return net.apply(params, (xi + t * vi)[None, :],
                                     unroll=True)[0, 0]
                lift = jax.grad
            else:
                def g(t):
                    return net.apply(params, (xi + t * vi)[None, :],
                                     unroll=True)[0]
                lift = jax.jacfwd
            outs, h = [], g
            for _ in range(order + 1):
                outs.append(h)
                h = lift(h)
            t0 = jnp.asarray(0.0, x.dtype)
            return jnp.stack([jnp.atleast_1d(o(t0)) for o in outs])

        return jnp.moveaxis(jax.vmap(along)(x, tangent), 0, 1)


# ---------------------------------------------------------------------------
# jax.experimental.jet: the independent Taylor-mode oracle
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class JaxJetEngine(DerivativeEngine):
    """JAX's own Taylor mode.  Quasilinear like NTP but a fully independent
    implementation (primitive-level jet rules vs our layer-level algebra), so
    agreement between the two certifies both.  Requires ``net.apply`` to be
    scan-free (``unroll=True``): jax.experimental.jet has no scan rule."""

    @property
    def spec(self) -> str:
        return "jet"

    def derivs(self, net: Network, params, x: jnp.ndarray, order: int,
               tangent: jnp.ndarray | None = None) -> jnp.ndarray:
        from jax.experimental import jet as jjet

        if tangent is None:
            tangent = jnp.ones_like(x)
        if order == 0:
            return net.apply(params, x)[None]
        series = [tangent.astype(x.dtype)] + \
            [jnp.zeros_like(x) for _ in range(order - 1)]
        y0, ys = jjet.jet(lambda xx: net.apply(params, xx, unroll=True),
                          (x,), (series,))
        return jnp.stack([y0] + list(ys))
