"""Core n-TangentProp: jets, Faa di Bruno tables, activation derivative stacks."""

from . import jet
from .activations import TAYLOR_STACKS, tanh_taylor_stack
from .jet import Jet
from .ntp import (MLPParams, init_mlp, mlp_apply, ntp_derivatives, ntp_forward,
                  ntp_grid, num_params)
from .partitions import (bell_number, faa_di_bruno_table, partition_count,
                         partitions, raw_bell_coefficient, total_fdb_terms)

__all__ = [
    "jet", "Jet", "TAYLOR_STACKS", "tanh_taylor_stack",
    "MLPParams", "init_mlp", "mlp_apply", "ntp_derivatives", "ntp_forward",
    "ntp_grid", "num_params",
    "bell_number", "faa_di_bruno_table", "partition_count", "partitions",
    "raw_bell_coefficient", "total_fdb_terms",
]
