"""Core n-TangentProp: jets, Faa di Bruno tables, activation derivative
stacks, the compositional jet-module layer, jet-traceable networks, and the
derivative-engine hierarchy."""

from . import jet, modules
from .activations import TAYLOR_STACKS, tanh_taylor_stack
from .engines import (AutodiffEngine, DerivativeEngine, EngineSpec,
                      JaxJetEngine, NTPEngine)
from .jet import Jet
from .modules import (Activation, CoordinateEmbedding, Dense, FourierFeatures,
                      MLPBlock, Module, Residual, RMSNorm, SelfAttention,
                      Sequential, TokenPool, make_module, module_names,
                      register_module)
from .network import (DenseMLP, MLP, FourierFeatureMLP, Network, ResidualMLP,
                      Transformer, make_network, network_names,
                      register_network)
from .ntp import (MLPParams, cross, init_mlp, mlp_apply, ntp_derivatives,
                  ntp_forward, ntp_grid, ntp_jet, num_params)
from .partitions import (bell_number, faa_di_bruno_table, partition_count,
                         partitions, raw_bell_coefficient, total_fdb_terms)

__all__ = [
    "jet", "Jet", "modules", "TAYLOR_STACKS", "tanh_taylor_stack",
    "AutodiffEngine", "DerivativeEngine", "EngineSpec", "JaxJetEngine",
    "NTPEngine",
    "Activation", "CoordinateEmbedding", "Dense", "FourierFeatures",
    "MLPBlock", "Module", "Residual", "RMSNorm", "SelfAttention",
    "Sequential", "TokenPool", "make_module", "module_names",
    "register_module",
    "DenseMLP", "MLP", "FourierFeatureMLP", "Network", "ResidualMLP",
    "Transformer", "make_network", "network_names", "register_network",
    "MLPParams", "cross", "init_mlp", "mlp_apply", "ntp_derivatives",
    "ntp_forward", "ntp_grid", "ntp_jet", "num_params",
    "bell_number", "faa_di_bruno_table", "partition_count", "partitions",
    "raw_bell_coefficient", "total_fdb_terms",
]
