"""Compositional jet-modules: reusable blocks every Network is built from.

PR 2 made the *engines* network-agnostic; this layer makes the *networks*
module-agnostic.  A :class:`Module` is the smallest jet-traceable unit --
``init`` / ``apply`` / ``jet_apply`` with exactly the Network contract
(``repro.core.network``), so a Network is just a Module with ``d_in``/
``d_out``/``activation`` metadata and combinators compose freely:

* **leaves** own parameters and the jet rules for one operation --
  :class:`Dense` (with the Pallas ``jet_dense`` fast path and fused
  activation epilogue), :class:`Activation`, :class:`FourierFeatures`,
  :class:`RMSNorm`, :class:`SelfAttention`, :class:`MLPBlock`,
  :class:`CoordinateEmbedding`, :class:`TokenPool`;
* **combinators** own structure only -- :class:`Sequential` (params are a
  tuple, one entry per child, keys split once per child in order) and
  :class:`Residual` (``x + inner(x)``; jet addition is coefficient-wise and
  exact, so skips cost nothing in derivative accuracy).

``jet_apply`` composes because every leaf pushes the *same* scaled-Taylor
jet representation (``repro.core.jet``): the stack ``(order+1, *shape)``
rides through linear maps coefficient-wise, through contractions as Cauchy
convolutions (attention scores!), and through smooth scalars via Faa di
Bruno.  ``impl="pallas"`` routes every Dense contraction through the fused
kernel dispatch (``repro.kernels.ops.jet_dense``, which accepts arbitrary
leading batch axes -- token axes included -- and fuses the activation
epilogue when ``ops.epilogues()`` marks the name ``ACTIVATION``), the whole
attention layer through the single-launch ``ops.jet_flash_attention`` and
rms_norm through ``ops.jet_rms_norm`` (the ``"flash_attention"`` /
``"rms_norm"`` ``FUSED_OP`` entries of the same typed epilogue registry);
anything unfused runs the reference jet algebra, so a module mixes kernel
and reference paths freely.  ``SelfAttention`` carries the attention-mask
surface (``mask=None | "causal" | ("local", window)``, canonicalized by
:func:`normalize_attention_mask`), honoured identically by the primal
``apply``, the jnp jet path (``J.softmax(mask=...)``), and the flash
kernel's per-block index test.

Leaves register themselves in a name -> factory registry
(:func:`register_module`) so configs and future conversion tools can build
graphs from data.  New blocks implement the three methods and slot into any
combinator; see ``repro.core.network.Transformer`` for the first non-MLP
consumer (pre-norm self-attention trunk over coordinate tokens).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from . import jet as J
from .activations import PRIMALS
from .ntp import xavier_uniform

Params = Any  # parameter pytree; structure owned by the module


class Module:
    """Smallest jet-traceable unit: the Network contract without metadata.

    Stateless modules keep the default ``init`` (empty params) but still
    consume one RNG key inside :class:`Sequential` so adding parameters to a
    block never reshuffles its siblings' initializations.
    """

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return ()

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        raise NotImplementedError

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        raise NotImplementedError


def _check_impl(impl: str) -> None:
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"unknown impl {impl!r} (want 'jnp' or 'pallas')")


def _has_epilogue(name: str) -> bool:
    """Lazy wrapper over the typed capability registry
    ``kernels.ops.epilogues()`` (kept lazy so the module layer imports
    without pulling the Pallas stack in)."""
    from repro.kernels import ops as kops
    return name in kops.epilogues()


def _is_activation_epilogue(name: str) -> bool:
    """Lazy: can the dense kernel run ``name`` in its Faa di Bruno epilogue
    (``epilogues()[name] is EpilogueKind.ACTIVATION``)?  The FUSED_OP
    entries ("rms_norm", "attention_scores", "flash_attention") are NOT
    dense epilogues and must take their own dispatch."""
    from repro.kernels import ops as kops
    return kops.epilogues().get(name) is kops.EpilogueKind.ACTIVATION


# every canonical attention-mask kind normalize_attention_mask can emit;
# the registry the parity sweep's mask coverage is asserted against
ATTENTION_MASK_KINDS = ("none", "causal", "local")


def normalize_attention_mask(mask) -> tuple:
    """Canonicalize an attention-mask spec to a hashable ``(kind, window)``
    pair: ``None``/"none" -> ("none", 0), "causal" -> ("causal", 0),
    ("local", w) -> ("local", int(w)) with w >= 1.  The single validation
    point shared by :class:`SelfAttention` and the flash-kernel dispatch in
    ``repro.kernels.ops``."""
    if mask is None or mask == "none" or mask == ("none", 0):
        return ("none", 0)
    if mask == "causal" or mask == ("causal", 0):
        return ("causal", 0)
    if (isinstance(mask, (tuple, list)) and len(mask) == 2
            and mask[0] == "local"):
        window = int(mask[1])
        if window < 1:
            raise ValueError(f"local attention window must be >= 1, "
                             f"got {mask[1]!r}")
        return ("local", window)
    raise ValueError(f"unknown attention mask {mask!r}; want None, "
                     "'causal', or ('local', window)")


def attention_mask(mask, t: int) -> jnp.ndarray | None:
    """Dense (T, T) boolean keep-matrix for a mask spec (None for "none"):
    what the jnp softmax path, the primal forward, and the flash-kernel
    backward recompute consume.  ``local(w)`` is a causal sliding window --
    query q attends keys j with ``q - w < j <= q`` -- so the diagonal is
    always kept and no query row is ever fully masked."""
    kind, window = normalize_attention_mask(mask)
    if kind == "none":
        return None
    qi = jnp.arange(t)[:, None]
    kj = jnp.arange(t)[None, :]
    keep = kj <= qi
    if kind == "local":
        keep = keep & ((qi - kj) < window)
    return keep


def dense_jet(jet: J.Jet, w: jnp.ndarray, b: jnp.ndarray | None,
              activation: str | None, impl: str) -> J.Jet:
    """One dense contraction (+ optional activation) on a jet, dispatched.

    The shared fast path for every module that multiplies a jet by a weight
    matrix: ``impl="pallas"`` runs the fused kernel (activation folded into
    the kernel epilogue when the table exists, else the kernel computes the
    linear part and the activation composes through the jet algebra);
    ``impl="jnp"`` is the reference algebra.  Arbitrary leading batch axes
    (collocation batch, token axis) are supported by both paths.
    """
    _check_impl(impl)
    if impl == "pallas":
        from repro.kernels import ops as kops
        if b is None:
            b = jnp.zeros((w.shape[1],), jet.dtype)
        # the narrow ACTIVATION-kind query, NOT bare membership: FUSED_OP
        # registry entries ("rms_norm", "attention_scores",
        # "flash_attention") are not dense epilogues and must take the
        # compose-after-kernel path
        if activation is None or _is_activation_epilogue(activation):
            return J.Jet(kops.jet_dense(jet.coeffs, w, b, activation))
        out = J.Jet(kops.jet_dense(jet.coeffs, w, b, None))
        return J.activation(out, activation)
    out = J.linear(jet, w, b)
    if activation is not None:
        out = J.activation(out, activation)
    return out


# ---------------------------------------------------------------------------
# leaf modules
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Dense(Module):
    """``act(x @ w + b)`` -- params ``(w, b)``; ``activation=None`` is the
    linear readout.  The jet path is the Pallas-fused layer of the paper's
    Algorithm 1."""

    d_in: int
    d_out: int
    activation: str | None = None

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return (xavier_uniform(key, self.d_in, self.d_out, dtype),
                jnp.zeros((self.d_out,), dtype))

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        w, b = params
        y = x @ w + b
        return PRIMALS[self.activation](y) if self.activation else y

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        w, b = params
        return dense_jet(jet, w, b, self.activation, impl)


@dataclass(frozen=True)
class Activation(Module):
    """Pointwise activation as its own (stateless) block.  Under
    ``impl="pallas"`` a table-backed activation runs the fused Faa di Bruno
    kernel (``ops.act_jet``); anything else composes through the algebra."""

    name: str

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        return PRIMALS[self.name](x)

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        _check_impl(impl)
        if impl == "pallas" and _is_activation_epilogue(self.name):
            from repro.kernels import ops as kops
            return J.Jet(kops.act_jet(jet.coeffs, self.name))
        return J.activation(jet, self.name)


@dataclass(frozen=True)
class FourierFeatures(Module):
    """``gamma(x) = [sin(2pi B x), cos(2pi B x)]`` with fixed Gaussian ``B``
    (Tancik et al. 2020).  Params are the bare ``B`` array, excluded from
    gradients via stop_gradient; the jet is exact (``sin`` through Faa di
    Bruno, ``cos z = sin(z + pi/2)`` reusing the same table)."""

    d_in: int
    n_features: int
    scale: float = 1.0

    @property
    def d_out(self) -> int:
        return 2 * self.n_features

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return self.scale * jax.random.normal(
            key, (self.d_in, self.n_features), dtype)

    def _freqs(self, B: jnp.ndarray) -> jnp.ndarray:
        return 2.0 * math.pi * jax.lax.stop_gradient(B)

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        z = x @ self._freqs(params)
        return jnp.concatenate([jnp.sin(z), jnp.cos(z)], axis=-1)

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        _check_impl(impl)
        z = J.linear(jet, self._freqs(params))
        s = J.compose(z, "sin")
        c = J.compose(J.add(z, 0.5 * math.pi), "sin")  # cos z = sin(z + pi/2)
        return J.jmap(lambda a, b: jnp.concatenate([a, b], axis=-1), s, c)


@dataclass(frozen=True)
class RMSNorm(Module):
    """Pre-norm RMS normalization over the trailing feature axis; params are
    the gain ``gamma`` (ones-init).  Smooth everywhere (rsqrt of a positive
    mean square), so the jet is exact at every order.  Under
    ``impl="pallas"`` the whole chain (mean-square convolution, rsqrt
    recurrence, gain) runs as the fused ``ops.jet_rms_norm`` kernel -- the
    ``"rms_norm"`` entry of the epilogue registry."""

    dim: int
    eps: float = 1e-6

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return jnp.ones((self.dim,), dtype)

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        ms = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + self.eps) * params

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        _check_impl(impl)
        if impl == "pallas" and _has_epilogue("rms_norm"):
            from repro.kernels import ops as kops
            return J.Jet(kops.jet_rms_norm(jet.coeffs, params, eps=self.eps))
        return J.rms_norm(jet, params, eps=self.eps)


@dataclass(frozen=True)
class SelfAttention(Module):
    """Multi-head scaled-dot-product self-attention over the token axis
    (``x``: (..., T, dim)).  Scores are a jet x jet Cauchy-convolved einsum,
    softmax goes through the exp/div power-series recurrences, and the value
    contraction is a second jet x jet einsum -- the whole block stays inside
    the quasilinear jet algebra (no nested autodiff anywhere).

    ``mask`` opens sequence-structured workloads: ``None`` (dense),
    ``"causal"``, or ``("local", window)`` -- a causal sliding window where
    query q attends keys j with ``q - window < j <= q``.  Both paths apply
    it as a t-constant ``where`` before the softmax recurrences, so masked
    probability jets vanish identically at every order.

    Under ``impl="pallas"`` the q/k/v projections ride the Pallas dense
    dispatch and everything downstream -- Cauchy QK^T, scale, masked
    softmax, value contraction, output projection -- runs as ONE tiled
    flash-jet launch (``ops.jet_flash_attention``, the ``"flash_attention"``
    registry entry): an online-softmax recurrence over KV blocks
    generalized to the coefficient axis, so the (Tq, Tk) score jet never
    materializes."""

    dim: int
    n_heads: int = 2
    mask: Any = None

    def __post_init__(self):
        if self.dim % self.n_heads:
            raise ValueError(f"dim={self.dim} not divisible by "
                             f"n_heads={self.n_heads}")
        # canonicalize (and validate) so equal masks hash equal and the
        # spec stays hashable inside the frozen dataclass
        kind, window = normalize_attention_mask(self.mask)
        canon = None if kind == "none" else \
            ("causal" if kind == "causal" else (kind, window))
        object.__setattr__(self, "mask", canon)

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        kq, kk, kv, ko = jax.random.split(key, 4)
        mk = lambda k: xavier_uniform(k, self.dim, self.dim, dtype)
        return {"wq": mk(kq), "wk": mk(kk), "wv": mk(kv), "wo": mk(ko)}

    def _split_heads(self, c: jnp.ndarray) -> jnp.ndarray:
        return c.reshape(c.shape[:-1] + (self.n_heads, self.head_dim))

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        q = self._split_heads(x @ params["wq"])
        k = self._split_heads(x @ params["wk"])
        v = self._split_heads(x @ params["wv"])
        s = jnp.einsum("...qhd,...khd->...hqk", q, k) / math.sqrt(self.head_dim)
        keep = attention_mask(self.mask, x.shape[-2])
        if keep is not None:
            s = jnp.where(keep, s, jnp.asarray(J.MASK_NEG, s.dtype))
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("...hqk,...khd->...qhd", p, v)
        return o.reshape(o.shape[:-2] + (self.dim,)) @ params["wo"]

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        split = lambda j: J.jmap(self._split_heads, j)
        q = split(dense_jet(jet, params["wq"], None, None, impl))
        k = split(dense_jet(jet, params["wk"], None, None, impl))
        v = split(dense_jet(jet, params["wv"], None, None, impl))
        scale = 1.0 / math.sqrt(self.head_dim)
        if impl == "pallas" and _has_epilogue("flash_attention"):
            # single tiled launch for the whole remaining block; the head
            # axis stays inside the kernel block so the output projection
            # (which mixes heads) can fold in as the epilogue
            from repro.kernels import ops as kops
            to_heads = lambda c: jnp.moveaxis(c, -2, -3)   # (..., H, T, D)
            return J.Jet(kops.jet_flash_attention(
                to_heads(q.coeffs), to_heads(k.coeffs), to_heads(v.coeffs),
                params["wo"], scale, mask=self.mask))
        s = J.scale(J.einsum("...qhd,...khd->...hqk", q, k), scale)
        p = J.softmax(s, axis=-1,
                      mask=attention_mask(self.mask, jet.shape[-2]))
        o = J.einsum("...hqk,...khd->...qhd", p, v)
        o = J.jmap(lambda c: c.reshape(c.shape[:-2] + (self.dim,)), o)
        return dense_jet(o, params["wo"], None, None, impl)


@dataclass(frozen=True)
class MLPBlock(Module):
    """Transformer feed-forward: ``Dense(dim, hidden, act) -> Dense(hidden,
    dim)``; params are the inner :class:`Sequential`'s tuple."""

    dim: int
    hidden: int
    activation: str = "tanh"

    def _seq(self) -> "Sequential":
        return Sequential((Dense(self.dim, self.hidden, self.activation),
                           Dense(self.hidden, self.dim, None)))

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return self._seq().init(key, dtype)

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        return self._seq().apply(params, x, unroll=unroll)

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        return self._seq().jet_apply(params, jet, impl=impl)


@dataclass(frozen=True)
class CoordinateEmbedding(Module):
    """Tokens from coordinates: input point ``x`` (..., d_in) becomes d_in
    tokens, token t = ``x_t * w[t] + b[t]`` (..., d_in, dim).  Each
    coordinate gets its own embedding row, so ``w``/``b`` double as learned
    positional encodings; the map is linear, hence jet-exact."""

    d_in: int
    dim: int

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return (xavier_uniform(key, self.d_in, self.dim, dtype),
                jnp.zeros((self.d_in, self.dim), dtype))

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        w, b = params
        return x[..., :, None] * w + b

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        _check_impl(impl)
        w, b = params
        coeffs = jet.coeffs[..., :, None] * w
        return J.Jet(coeffs.at[0].add(b))


@dataclass(frozen=True)
class TokenPool(Module):
    """Mean over the token axis (..., T, dim) -> (..., dim); linear, so the
    jet reduces coefficient-wise."""

    axis: int = -2

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        return jnp.mean(x, axis=self.axis)

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        _check_impl(impl)
        return J.reduce_mean(jet, axis=self.axis)


# ---------------------------------------------------------------------------
# combinators
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Sequential(Module):
    """Compose modules left to right.  Params are a tuple with one entry per
    child; ``init`` splits the key once per child *in order*, so a graph's
    initialization is a pure function of its structure (and a Sequential of
    Dense leaves reproduces the historical MLP init bit for bit)."""

    modules: Tuple[Module, ...]

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        ks = jax.random.split(key, len(self.modules))
        return tuple(m.init(k, dtype) for m, k in zip(self.modules, ks))

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        for m, p in zip(self.modules, params):
            x = m.apply(p, x, unroll=unroll)
        return x

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        for m, p in zip(self.modules, params):
            jet = m.jet_apply(p, jet, impl=impl)
        return jet


@dataclass(frozen=True)
class Residual(Module):
    """``x + inner(x)``: params are the inner module's.  Jet addition is
    coefficient-wise, so the skip is exact at every derivative order and
    costs nothing beyond the inner block."""

    inner: Module

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return self.inner.init(key, dtype)

    def apply(self, params: Params, x: jnp.ndarray, *,
              unroll: bool = False) -> jnp.ndarray:
        return x + self.inner.apply(params, x, unroll=unroll)

    def jet_apply(self, params: Params, jet: J.Jet, *,
                  impl: str = "jnp") -> J.Jet:
        return J.add(jet, self.inner.jet_apply(params, jet, impl=impl))


# ---------------------------------------------------------------------------
# leaf registry: named factories for configs / conversion tools
# ---------------------------------------------------------------------------

ModuleFactory = Callable[..., Module]

_MODULES: Dict[str, ModuleFactory] = {}


def register_module(name: str, factory: ModuleFactory) -> None:
    if name in _MODULES:
        raise ValueError(f"module {name!r} already registered")
    _MODULES[name] = factory


def module_names() -> Tuple[str, ...]:
    return tuple(sorted(_MODULES))


def make_module(name: str, **kwargs) -> Module:
    if name not in _MODULES:
        raise KeyError(f"unknown module {name!r}; known: {module_names()}")
    return _MODULES[name](**kwargs)


for _name, _factory in (
    ("dense", Dense),
    ("activation", Activation),
    ("fourier_features", FourierFeatures),
    ("rms_norm", RMSNorm),
    ("self_attention", SelfAttention),
    ("mlp_block", MLPBlock),
    ("coordinate_embedding", CoordinateEmbedding),
    ("token_pool", TokenPool),
    ("sequential", Sequential),
    ("residual", Residual),
):
    register_module(_name, _factory)
