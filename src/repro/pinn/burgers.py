"""Self-similar Burgers profiles (paper section IV-C): equation, exact
solution, and jet-based residual derivatives.

ODE (paper eq. 7):      R(U, X) = -lam U + ((1+lam) X + U) U' = 0
Implicit solution (8):  X = -U - C U^{1 + 1/lam}
Smooth profiles:        lam = 1/(2k), k = 1, 2, ... (odd, C^inf solutions)

The k-th profile is found by constraining lam to [1/(2k+1), 1/(2k-1)] and
penalizing |d^n/dX^n R| near the origin with n = 2k+1 -- non-smooth profiles
in that window have a discontinuity there by order 2k+1, so the penalty gives
gradient signal pushing lam to 1/(2k).  Computing d^n R needs n+1 network
derivatives: the paper's motivating workload for n-TangentProp.
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jet as J
from repro.core.ntp import MLPParams, mlp_apply, ntp_forward


def profile_lambda(k: int) -> float:
    return 1.0 / (2 * k)


def lambda_window(k: int) -> tuple[float, float]:
    return 1.0 / (2 * k + 1), 1.0 / (2 * k - 1)


def smoothness_order(k: int) -> int:
    """Derivative order of R penalized at the origin (paper: 2k+1)."""
    return 2 * k + 1


# ---------------------------------------------------------------------------
# exact solution (oracle for accuracy reporting; C = 1 normalization)
# ---------------------------------------------------------------------------

def exact_profile(x: np.ndarray, k: int, c: float = 1.0,
                  tol: float = 1e-13, iters: int = 200) -> np.ndarray:
    """Invert X = -U - c U^(2k+1) by bisection (X monotone decreasing in U)."""
    p = 2 * k + 1
    x = np.asarray(x, np.float64)
    # bracket: U in [-Umax, Umax] with Umax solving Umax + c Umax^p = max|X|
    xm = float(np.max(np.abs(x))) + 1.0
    hi = max(xm, xm ** (1.0 / p))
    lo_all = np.full_like(x, -hi)
    hi_all = np.full_like(x, hi)

    def f(u):
        return -u - c * u ** p - x  # f is decreasing in u

    for _ in range(iters):
        mid = 0.5 * (lo_all + hi_all)
        val = f(mid)
        lo_all = np.where(val > 0, mid, lo_all)   # f>0 -> root is above mid
        hi_all = np.where(val > 0, hi_all, mid)
        if np.max(hi_all - lo_all) < tol:
            break
    return 0.5 * (lo_all + hi_all)


# ---------------------------------------------------------------------------
# residual jets (n-TangentProp engine)
# ---------------------------------------------------------------------------

def jet_derivative(j: J.Jet) -> J.Jet:
    """d/dt of a jet: coeffs'_k = (k+1) c_{k+1} (order drops by one)."""
    n = j.order
    ks = jnp.arange(1, n + 1, dtype=j.coeffs.dtype)
    return J.Jet(j.coeffs[1:] * ks.reshape((-1,) + (1,) * len(j.shape)))


def residual_jet(params: MLPParams, lam, x: jnp.ndarray, order: int,
                 activation: str = "tanh", impl: str = "jnp") -> J.Jet:
    """Jet of R along X at each collocation point; R-jet order = ``order``.

    Needs the u-jet to order+1 (R contains U').  One n-TangentProp pass."""
    u = ntp_forward(params, x, order + 1, activation=activation,
                    impl=impl)                             # (order+2, N, 1)
    up = jet_derivative(u)                                 # order+1
    u = J.Jet(u.coeffs[:order + 1])                        # truncate to order
    up = J.Jet(up.coeffs[:order + 1])
    xj = J.seed(x, jnp.ones_like(x), order)
    adv = J.add(J.scale(xj, 1.0 + lam), u)                 # (1+lam) X + U
    return J.add(J.scale(u, -lam), J.mul(adv, up))


def residual_derivs_autodiff(params: MLPParams, lam, x: jnp.ndarray,
                             order: int, activation: str = "tanh") -> jnp.ndarray:
    """Baseline: same quantities via nested autodiff (O(M^n) graph).

    Returns (order+1, N, 1) raw derivatives of R, matching
    J.derivatives(residual_jet(...))."""

    def u_fn(xs):
        return mlp_apply(params, xs[None, :], activation, unroll=True)[0, 0]

    def r_fn(xs):
        u = u_fn(xs)
        up = jax.grad(u_fn)(xs)[0]
        return -lam * u + ((1.0 + lam) * xs[0] + u) * up

    def all_derivs(xi):
        outs = []
        h = lambda t: r_fn(xi + jnp.array([1.0], xi.dtype) * t)
        for _ in range(order + 1):
            outs.append(h)
            h = jax.grad(h)
        return jnp.stack([o(jnp.asarray(0.0, xi.dtype)) for o in outs])

    return jax.vmap(all_derivs)(x).T[..., None]
