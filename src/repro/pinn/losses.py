"""PINN loss assembly, generic over a differential operator.

``pinn_loss`` is the operator-generic objective: residual MSE over interior
collocation points plus boundary/initial supervision against the operator's
exact solution, generic over the :class:`DerivativeEngine` (``NTPEngine``
quasilinear vs ``AutodiffEngine`` baseline, by object or spec string) and
the :class:`Network` (``net=``; defaults to the :class:`DenseMLP` view of a
bare ``MLPParams`` for backward compatibility).  The self-similar Burgers
workload keeps its specialized objective (learnable lambda, Sobolev term,
high-order origin smoothness -- paper eq. 1, 2 and appendix A) as
``burgers_pinn_loss``; its residual algebra is also registered in the
operator registry as ``"burgers"``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import jet as J
from repro.core.engines import DerivativeEngine
from repro.core.network import Network
from repro.core.ntp import MLPParams, mlp_apply

from .burgers import exact_profile, residual_derivs_autodiff, residual_jet
from .operators import Operator, build_table, get_operator, resolve_net_engine


@dataclass(frozen=True)
class LossWeights:
    residual: float = 1.0
    sobolev1: float = 0.1     # Q_1 of the Sobolev loss (paper eq. 2, m=1)
    origin: float = 1.0e-3    # high-order smoothness at the origin (L*)
    bc: float = 10.0


# ---------------------------------------------------------------------------
# generic operator objective
# ---------------------------------------------------------------------------

def pinn_loss(params, *, op: Union[Operator, str], pts: jnp.ndarray,
              bc_pts: jnp.ndarray, bc_vals: jnp.ndarray,
              weights: LossWeights = LossWeights(),
              engine: Union[str, DerivativeEngine] = "ntp",
              impl: str = "jnp", activation: str = "tanh",
              net: Network | None = None) -> Tuple[jnp.ndarray, Dict]:
    """Operator-generic PINN objective: w_r ||R[u]||^2 + w_bc ||u - u*||^2_bd.

    ``bc_vals`` is the exact solution on ``bc_pts`` -- precompute it outside
    jit (``op.exact`` may be numpy-backed, e.g. the Burgers profile).  Only
    ``engine``/``net`` change the derivative machinery and architecture; the
    loss surface is identical across engines (the paper's "exact method"
    property).  Scalar networks only: a vector-valued ``net`` (d_out > 1)
    raises instead of silently supervising the first output component.
    """
    if isinstance(op, str):
        op = get_operator(op)
    net, eng = resolve_net_engine(params, net, engine, impl, activation)
    if net.d_out != 1:
        raise ValueError(
            "pinn_loss supervises a scalar field u but the network has "
            f"d_out={net.d_out}; slicing [:, 0] would silently drop the other "
            "components.  Use a d_out=1 network (vector-valued PDE systems "
            "are a ROADMAP item).")
    r = op.residual(pts, build_table(net, params, eng, op, pts))
    l_res = jnp.mean(r ** 2)
    ub = net.apply(params, bc_pts)[:, 0]
    l_bc = jnp.mean((ub - bc_vals) ** 2)
    loss = weights.residual * l_res + weights.bc * l_bc
    return loss, {"residual": l_res, "bc": l_bc}


# ---------------------------------------------------------------------------
# the self-similar Burgers objective (paper section IV-C)
# ---------------------------------------------------------------------------

def _burgers_engine(engine: Union[str, DerivativeEngine],
                    impl: str) -> Tuple[str, str]:
    """The specialized Burgers jet pipeline predates the engine objects;
    normalize any accepted engine form back to its ("ntp"|"autodiff", impl)
    string pair."""
    from repro.core.engines import AutodiffEngine, NTPEngine, resolve_engine
    eng = resolve_engine(engine, impl)
    if isinstance(eng, NTPEngine):
        return "ntp", eng.impl
    if isinstance(eng, AutodiffEngine):
        return "autodiff", impl
    raise ValueError(f"burgers objective supports the ntp and autodiff "
                     f"engines, not {eng.spec!r}")


def bc_targets(k: int, domain: float) -> Tuple[float, float]:
    """U_true(+-L) with the C=1 normalization."""
    import numpy as np
    vals = exact_profile(np.array([-domain, domain]), k)
    return float(vals[0]), float(vals[1])


def burgers_pinn_loss(params: MLPParams, lam_raw: jnp.ndarray, *, k: int,
                      pts: jnp.ndarray, origin_pts: jnp.ndarray, domain: float,
                      order: int, weights: LossWeights,
                      lam_window: Tuple[float, float], engine: str = "ntp",
                      impl: str = "jnp", activation: str = "tanh",
                      bc_vals: Tuple[float, float] = None) -> Tuple[jnp.ndarray, Dict]:
    """Full self-similar Burgers objective.  ``engine``: "ntp" (quasilinear,
    ours) or "autodiff" (the paper's baseline), as a string, spec
    ("ntp/pallas"), or :class:`DerivativeEngine` instance.  Everything else
    is identical, so the benchmark isolates the derivative engine."""
    engine, impl = _burgers_engine(engine, impl)
    lo, hi = lam_window
    lam = lo + (hi - lo) * jax.nn.sigmoid(lam_raw)

    if engine == "ntp":
        # one jet to order 1 on the full domain (residual + Sobolev-1) ...
        r_dom = J.derivatives(residual_jet(params, lam, pts, 1,
                                           activation=activation, impl=impl))
        # ... and one high-order jet on the origin cluster
        r_org = J.derivatives(residual_jet(params, lam, origin_pts, order,
                                           activation=activation, impl=impl))
    else:
        r_dom = residual_derivs_autodiff(params, lam, pts, 1, activation)
        r_org = residual_derivs_autodiff(params, lam, origin_pts, order, activation)

    l_res = jnp.mean(r_dom[0] ** 2)
    l_sob = jnp.mean(r_dom[1] ** 2)
    l_org = jnp.mean(r_org[order] ** 2)

    # boundary conditions: U(0)=0, U'(0)=-1, U(+-L) pinned to the C=1 profile
    x0 = jnp.zeros((1, 1), pts.dtype)
    u0j = J.derivatives(residual_jet_u(params, x0, activation=activation,
                                       impl=impl))
    u0, du0 = u0j[0, 0, 0], u0j[1, 0, 0]
    xb = jnp.asarray([[-domain], [domain]], pts.dtype)
    ub = mlp_apply(params, xb, activation)
    tb = jnp.asarray(bc_vals, pts.dtype)
    l_bc = u0 ** 2 + (du0 + 1.0) ** 2 + jnp.mean((ub[:, 0] - tb) ** 2)

    loss = (weights.residual * l_res + weights.sobolev1 * l_sob +
            weights.origin * l_org + weights.bc * l_bc)
    return loss, {"residual": l_res, "sobolev1": l_sob, "origin": l_org,
                  "bc": l_bc, "lambda": lam}


def residual_jet_u(params: MLPParams, x: jnp.ndarray, activation: str = "tanh",
                   impl: str = "jnp") -> J.Jet:
    """Order-1 jet of U itself (for the U(0), U'(0) boundary terms)."""
    from repro.core.ntp import ntp_forward
    return ntp_forward(params, x, 1, activation=activation, impl=impl)
