"""PINN loss assembly, generic over a differential operator.

``pinn_loss`` is the operator-generic objective: residual MSE over interior
collocation points plus boundary/initial supervision against the operator's
exact solution, generic over the :class:`DerivativeEngine` (``NTPEngine``
quasilinear vs ``AutodiffEngine`` baseline, by object or spec string), the
:class:`Network` (``net=``, required -- the loss never guesses the
architecture from a parameter pytree), and the operator's output rank:
scalar PDEs and multi-equation systems (``op.d_out > 1``, e.g. Gray-Scott)
run through the same code path, with boundary supervision across every
component.  The self-similar Burgers workload keeps its specialized
objective (learnable lambda, Sobolev term, high-order origin smoothness --
paper eq. 1, 2 and appendix A) as ``burgers_pinn_loss``; its residual
algebra is also registered in the operator registry as ``"burgers"``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import jet as J
from repro.core.engines import DerivativeEngine
from repro.core.network import Network
from repro.core.ntp import MLPParams, mlp_apply

from .burgers import exact_profile, residual_derivs_autodiff, residual_jet
from .operators import Operator, build_table, get_operator


@dataclass(frozen=True)
class LossWeights:
    residual: float = 1.0
    sobolev1: float = 0.1     # Q_1 of the Sobolev loss (paper eq. 2, m=1)
    origin: float = 1.0e-3    # high-order smoothness at the origin (L*)
    bc: float = 10.0


# ---------------------------------------------------------------------------
# generic operator objective
# ---------------------------------------------------------------------------

def pinn_loss(params, *, op: Union[Operator, str], pts: jnp.ndarray,
              bc_pts: jnp.ndarray, bc_vals: jnp.ndarray, net: Network,
              weights: LossWeights = LossWeights(),
              engine: Union[str, DerivativeEngine] = "ntp",
              mesh=None) -> Tuple[jnp.ndarray, Dict]:
    """Operator-generic PINN objective: w_r ||R[u]||^2 + w_bc ||u - u*||^2_bd.

    ``bc_vals`` is the exact solution on ``bc_pts`` -- (N,) for scalar
    operators, (N, d_out) for systems; precompute it outside jit
    (``op.exact`` may be numpy-backed, e.g. the Burgers profile;
    :func:`repro.pinn.operators.exact_values` normalizes the shape).  For a
    multi-equation system the residual term averages the squares of every
    equation and the boundary term supervises every output component.  Only
    ``engine``/``net`` change the derivative machinery and architecture; the
    loss surface is identical across engines (the paper's "exact method"
    property).  ``mesh`` (a ``jax.sharding.Mesh`` with a ``"data"`` axis)
    shards the residual's grid/cross calls over the mesh's data axis via
    :class:`repro.parallel.jet_shard.ShardedEngine` -- same loss value (bit
    identical for the ntp engines), collocation batch split across devices.
    """
    if isinstance(op, str):
        op = get_operator(op)
    eng = DerivativeEngine.from_spec(engine)
    if mesh is not None:
        from repro.parallel.jet_shard import ShardedEngine
        eng = ShardedEngine(eng, mesh)
    r = op.residual(pts, build_table(net, params, eng, op, pts))
    l_res = jnp.mean(r ** 2)
    ub = net.apply(params, bc_pts)                       # (Nb, d_out)
    bv = jnp.asarray(bc_vals)
    if bv.ndim == 1:
        bv = bv[:, None]
    if bv.shape != ub.shape:
        raise ValueError(
            f"bc_vals shape {bv.shape} does not match the network's boundary "
            f"output {ub.shape}; systems need one column per component")
    l_bc = jnp.mean((ub - bv) ** 2)
    loss = weights.residual * l_res + weights.bc * l_bc
    return loss, {"residual": l_res, "bc": l_bc}


# ---------------------------------------------------------------------------
# the self-similar Burgers objective (paper section IV-C)
# ---------------------------------------------------------------------------

def _burgers_engine(engine: Union[str, DerivativeEngine]) -> Tuple[str, str]:
    """The specialized Burgers jet pipeline predates the engine objects;
    normalize a spec string or engine instance back to its
    ("ntp"|"autodiff", impl) string pair."""
    from repro.core.engines import AutodiffEngine, DerivativeEngine, NTPEngine
    eng = DerivativeEngine.from_spec(engine)
    if isinstance(eng, NTPEngine):
        return "ntp", eng.impl
    if isinstance(eng, AutodiffEngine):
        return "autodiff", "jnp"
    raise ValueError(f"burgers objective supports the ntp and autodiff "
                     f"engines, not {eng.spec!r}")


def bc_targets(k: int, domain: float) -> Tuple[float, float]:
    """U_true(+-L) with the C=1 normalization."""
    import numpy as np
    vals = exact_profile(np.array([-domain, domain]), k)
    return float(vals[0]), float(vals[1])


def burgers_pinn_loss(params: MLPParams, lam_raw: jnp.ndarray, *, k: int,
                      pts: jnp.ndarray, origin_pts: jnp.ndarray, domain: float,
                      order: int, weights: LossWeights,
                      lam_window: Tuple[float, float], engine: str = "ntp",
                      activation: str = "tanh",
                      bc_vals: Tuple[float, float] = None) -> Tuple[jnp.ndarray, Dict]:
    """Full self-similar Burgers objective.  ``engine``: a spec string
    ("ntp", "ntp/pallas", "autodiff") or :class:`DerivativeEngine` instance.
    Everything else is identical, so the benchmark isolates the derivative
    engine."""
    engine, impl = _burgers_engine(engine)
    lo, hi = lam_window
    lam = lo + (hi - lo) * jax.nn.sigmoid(lam_raw)

    if engine == "ntp":
        # one jet to order 1 on the full domain (residual + Sobolev-1) ...
        r_dom = J.derivatives(residual_jet(params, lam, pts, 1,
                                           activation=activation, impl=impl))
        # ... and one high-order jet on the origin cluster
        r_org = J.derivatives(residual_jet(params, lam, origin_pts, order,
                                           activation=activation, impl=impl))
    else:
        r_dom = residual_derivs_autodiff(params, lam, pts, 1, activation)
        r_org = residual_derivs_autodiff(params, lam, origin_pts, order, activation)

    l_res = jnp.mean(r_dom[0] ** 2)
    l_sob = jnp.mean(r_dom[1] ** 2)
    l_org = jnp.mean(r_org[order] ** 2)

    # boundary conditions: U(0)=0, U'(0)=-1, U(+-L) pinned to the C=1 profile
    x0 = jnp.zeros((1, 1), pts.dtype)
    u0j = J.derivatives(residual_jet_u(params, x0, activation=activation,
                                       impl=impl))
    u0, du0 = u0j[0, 0, 0], u0j[1, 0, 0]
    xb = jnp.asarray([[-domain], [domain]], pts.dtype)
    ub = mlp_apply(params, xb, activation)
    tb = jnp.asarray(bc_vals, pts.dtype)
    l_bc = u0 ** 2 + (du0 + 1.0) ** 2 + jnp.mean((ub[:, 0] - tb) ** 2)

    loss = (weights.residual * l_res + weights.sobolev1 * l_sob +
            weights.origin * l_org + weights.bc * l_bc)
    return loss, {"residual": l_res, "sobolev1": l_sob, "origin": l_org,
                  "bc": l_bc, "lambda": lam}


def residual_jet_u(params: MLPParams, x: jnp.ndarray, activation: str = "tanh",
                   impl: str = "jnp") -> J.Jet:
    """Order-1 jet of U itself (for the U(0), U'(0) boundary terms)."""
    from repro.core.ntp import ntp_forward
    return ntp_forward(params, x, 1, activation=activation, impl=impl)
