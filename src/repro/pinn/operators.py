"""Differential-operator subsystem: PDE residuals as jet-primitive compositions.

n-TangentProp turns "evaluate u and its pure derivatives at collocation
points" into one quasilinear jet forward per coordinate axis (core/ntp.py).
This module layers a small abstraction on top so a PDE residual is written
ONCE against a derivative table and runs through every engine:

* ``engine="ntp"``      -- per-axis jets via :func:`repro.core.ntp.ntp_grid`
                           (``impl="jnp"`` reference or ``impl="pallas"``
                           fused kernels);
* ``engine="autodiff"`` -- nested ``jax.grad`` towers (the paper's baseline);
* the same residual applied to an *analytic* function via
  :func:`residual_of_fn` -- which is how each operator's manufactured/exact
  solution becomes a test oracle (method of manufactured solutions: the
  residual of the exact solution must vanish identically).

An :class:`Operator` declares its input dimension, the highest pure-derivative
order it consumes, a residual ``R(x, d)`` where ``d(axis, k)`` returns the
k-th pure derivative of u along ``axis`` at every collocation point, and an
exact solution over its default domain box.  Registered operators:

===========  ====  =====  ==========================================
name         d_in  order  residual
===========  ====  =====  ==========================================
heat          2     2     u_t - nu u_xx
wave          2     2     u_tt - c^2 u_xx
kdv           2     3     u_t + 6 u u_x + u_xxx
allen-cahn    2     2     u_t - eps u_xx + u^3 - u - f(t, x)
poisson2d     2     2     u_xx + u_yy - f(x, y)
burgers       1     1     -lam u + ((1 + lam) x + u) u'  (self-similar ODE)
===========  ====  =====  ==========================================

Mixed partials, when an operator needs them, come from the polarization
helper :func:`repro.core.ntp.cross` -- still 2^m directional jets, never a
nested-autodiff graph.  New PDEs register with :func:`register`; see
README.md for a walkthrough.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ntp import MLPParams, mlp_apply, ntp_grid

# d(axis, k) -> (N,) raw k-th pure derivative of u along axis
DerivTable = Callable[[int, int], jnp.ndarray]


@dataclass(frozen=True)
class Operator:
    """A differential operator with a manufactured/exact solution oracle.

    ``residual(x, d)`` consumes collocation points ``x`` of shape
    (N, d_in) and a :data:`DerivTable`; it returns the pointwise residual
    (N,).  ``exact(x)`` is the solution the residual vanishes on; it doubles
    as boundary/initial data for training and as the accuracy oracle in
    tests.  ``differentiable_exact`` is False when ``exact`` is not a pure
    jax function (e.g. the Burgers profile's bisection inversion), which
    excludes it from autodiff-based oracle checks only.
    """

    name: str
    d_in: int
    order: int
    residual: Callable[[jnp.ndarray, DerivTable], jnp.ndarray]
    exact: Callable[[jnp.ndarray], jnp.ndarray]
    domain: Tuple[Tuple[float, float], ...]
    description: str = ""
    differentiable_exact: bool = True


_REGISTRY: Dict[str, Operator] = {}


def register(op: Operator) -> Operator:
    if op.name in _REGISTRY:
        raise ValueError(f"operator {op.name!r} already registered")
    if len(op.domain) != op.d_in:
        raise ValueError(f"operator {op.name!r}: domain rank {len(op.domain)} "
                         f"!= d_in {op.d_in}")
    _REGISTRY[op.name] = op
    return op


def get_operator(name: str) -> Operator:
    if name not in _REGISTRY:
        raise KeyError(f"unknown operator {name!r}; known: {operator_names()}")
    return _REGISTRY[name]


def operator_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# derivative-table engines
# ---------------------------------------------------------------------------

def ntp_pure_derivs(params: MLPParams, x: jnp.ndarray, order: int,
                    activation: str = "tanh", impl: str = "jnp") -> jnp.ndarray:
    """(d_in, order+1, N) raw pure derivatives of the network, one jet batch."""
    return ntp_grid(params, x, order, activation, impl)[..., 0]


def autodiff_pure_derivs_fn(fn: Callable[[jnp.ndarray], jnp.ndarray],
                            x: jnp.ndarray, order: int) -> jnp.ndarray:
    """(d_in, order+1, N) pure derivatives of any scalar fn((d_in,)) -> ()
    via nested ``jax.grad`` towers -- the O(M^order) baseline and the oracle
    path for analytic solutions."""
    d = x.shape[-1]

    def one_axis(v):
        def tower(xi):
            h = lambda t: fn(xi + v * t)
            outs = []
            for _ in range(order + 1):
                outs.append(h)
                h = jax.grad(h)
            return jnp.stack([o(jnp.asarray(0.0, x.dtype)) for o in outs])

        return jax.vmap(tower)(x)            # (N, order+1)

    eye = jnp.eye(d, dtype=x.dtype)
    return jnp.transpose(jax.vmap(one_axis)(eye), (0, 2, 1))


def _table(D: jnp.ndarray) -> DerivTable:
    return lambda axis, k: D[axis, k]


def residual_values(params: MLPParams, op: Operator, x: jnp.ndarray, *,
                    engine: str = "ntp", activation: str = "tanh",
                    impl: str = "jnp") -> jnp.ndarray:
    """Pointwise residual (N,) of the network under ``op``."""
    if engine == "ntp":
        D = ntp_pure_derivs(params, x, op.order, activation, impl)
    elif engine == "autodiff":
        fn = lambda xi: mlp_apply(params, xi[None, :], activation, unroll=True)[0, 0]
        D = autodiff_pure_derivs_fn(fn, x, op.order)
    else:
        raise ValueError(f"unknown engine {engine!r} (want 'ntp' or 'autodiff')")
    return op.residual(x, _table(D))


def residual_of_fn(op: Operator, fn: Callable[[jnp.ndarray], jnp.ndarray],
                   x: jnp.ndarray) -> jnp.ndarray:
    """Residual of an arbitrary differentiable scalar function (the MMS oracle:
    ``residual_of_fn(op, exact, x) == 0`` certifies the operator's algebra)."""
    return op.residual(x, _table(autodiff_pure_derivs_fn(fn, x, op.order)))


# ---------------------------------------------------------------------------
# registered operators (coefficients chosen so no term degenerates)
# ---------------------------------------------------------------------------

HEAT_NU = 0.5
WAVE_C = 2.0
KDV_C = 4.0           # soliton speed
AC_EPS = 0.4
_PI = float(np.pi)


def _heat_residual(x, d):
    return d(0, 1) - HEAT_NU * d(1, 2)


def _heat_exact(x):
    return jnp.exp(-HEAT_NU * x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="heat", d_in=2, order=2,
    residual=_heat_residual, exact=_heat_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_t - nu u_xx;  exact u = exp(-nu t) sin x",
))


def _wave_residual(x, d):
    return d(0, 2) - WAVE_C ** 2 * d(1, 2)


def _wave_exact(x):
    return jnp.sin(x[:, 1] - WAVE_C * x[:, 0])


register(Operator(
    name="wave", d_in=2, order=2,
    residual=_wave_residual, exact=_wave_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_tt - c^2 u_xx;  exact u = sin(x - c t)",
))


def _kdv_residual(x, d):
    u = d(0, 0)
    return d(0, 1) + 6.0 * u * d(1, 1) + d(1, 3)


def _kdv_exact(x):
    arg = 0.5 * jnp.sqrt(KDV_C) * (x[:, 1] - KDV_C * x[:, 0])
    return 0.5 * KDV_C / jnp.cosh(arg) ** 2


register(Operator(
    name="kdv", d_in=2, order=3,
    residual=_kdv_residual, exact=_kdv_exact,
    domain=((0.0, 0.4), (-8.0, 8.0)),
    description="u_t + 6 u u_x + u_xxx;  exact single soliton, speed c",
))


def _ac_forcing(x):
    # manufactured solution u* = exp(-t) sin x:
    # u*_t - eps u*_xx + u*^3 - u* = (eps - 2) s + s^3,  s = exp(-t) sin x
    s = jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1])
    return (AC_EPS - 2.0) * s + s ** 3


def _ac_residual(x, d):
    u = d(0, 0)
    return d(0, 1) - AC_EPS * d(1, 2) + u ** 3 - u - _ac_forcing(x)


def _ac_exact(x):
    return jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="allen-cahn", d_in=2, order=2,
    residual=_ac_residual, exact=_ac_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_t - eps u_xx + u^3 - u - f;  manufactured u = exp(-t) sin x",
))


def _poisson_residual(x, d):
    # forcing f = -2 sin x sin y, so u = sin x sin y solves u_xx + u_yy = f
    return d(0, 2) + d(1, 2) + 2.0 * jnp.sin(x[:, 0]) * jnp.sin(x[:, 1])


def _poisson_exact(x):
    return jnp.sin(x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="poisson2d", d_in=2, order=2,
    residual=_poisson_residual, exact=_poisson_exact,
    domain=((0.0, _PI), (0.0, _PI)),
    description="u_xx + u_yy - f;  exact u = sin x sin y (zero on the boundary)",
))


def burgers_operator(lam: float = 0.5, k: int = 1,
                     domain: float = 2.0) -> Operator:
    """Self-similar Burgers profile ODE (paper eq. 7) as a registry operator.

    The specialized trainer (losses.burgers_pinn_loss) keeps its learnable-
    lambda objective; this fixed-lambda form slots the same residual into the
    generic operator surface.  Exact profile inverts X = -U - U^{2k+1} by
    bisection (numpy), hence ``differentiable_exact=False``.
    """
    def residual(x, d):
        u = d(0, 0)
        return -lam * u + ((1.0 + lam) * x[:, 0] + u) * d(0, 1)

    def exact(x):
        from .burgers import exact_profile
        return jnp.asarray(exact_profile(np.asarray(x[:, 0]), k),
                           dtype=x.dtype)

    return Operator(
        name="burgers", d_in=1, order=1, residual=residual, exact=exact,
        domain=((-domain, domain),),
        description="-lam u + ((1+lam) X + u) u';  exact implicit profile",
        differentiable_exact=False,
    )


register(burgers_operator())
