"""Differential-operator subsystem: PDE residuals as jet-primitive compositions.

n-TangentProp turns "evaluate u and its derivatives at collocation points"
into one quasilinear jet forward per direction (core/engines.py).  This
module layers a small abstraction on top so a PDE residual is written ONCE
against a derivative table and runs through every
:class:`repro.core.engines.DerivativeEngine` and every jet-traceable
:class:`repro.core.network.Network`:

* ``residual_values(params, op, x, net=..., engine=NTPEngine("pallas"))`` --
  any engine (ntp jnp/pallas, autodiff baseline, jax.experimental.jet
  oracle) x any network (DenseMLP, MLP, ResidualMLP, FourierFeatureMLP);
* the same residual applied to an *analytic* function via
  :func:`residual_of_fn` -- which is how each operator's manufactured/exact
  solution becomes a test oracle (method of manufactured solutions: the
  residual of the exact solution must vanish identically).

The whole surface is vector-valued: an :class:`Operator` carries ``d_out``
(the number of unknown field components) and its residual may return one
equation (``(N,)``) or a stacked system (``(n_eq, N)``).  The
:class:`DerivTable` indexes components -- ``d(axis, k, comp=c)`` and
``d.mixed(*axes, comp=c)`` -- with ``comp=0`` the default so every scalar
residual reads exactly as the math.

An :class:`Operator` declares its input dimension, the highest pure-
derivative order it consumes, the mixed partials it needs (``mixed``, a
tuple of axis tuples -- served through polarization, ``engine.cross``), a
residual ``R(x, d)``, and an exact solution over its default domain box
(shape (N,) for scalar operators, (N, d_out) for systems).  Registered:

===================  ====  =====  =====  =================================
name                 d_in  d_out  order  residual
===================  ====  =====  =====  =================================
heat                  2     1      2     u_t - nu u_xx
wave                  2     1      2     u_tt - c^2 u_xx
kdv                   2     1      3     u_t + 6 u u_x + u_xxx
allen-cahn            2     1      2     u_t - eps u_xx + u^3 - u - f(t, x)
poisson2d             2     1      2     u_xx + u_yy - f(x, y)
advection-diffusion   3     1      2     u_t + a.grad u - div(D grad u) - f,
                                         rotated anisotropic D (u_xy term)
navier-stokes         2     1      4     steady streamfunction-vorticity:
                                         nu lap^2 psi + psi_y d_x(lap psi)
                                         - psi_x d_y(lap psi) - f
                                         (psi_xxyy via 4th-order
                                         polarization)
gray-scott            2     2      2     coupled reaction-diffusion system,
                                         one residual per component
burgers               1     1      1     -lam u + ((1 + lam) x + u) u'
                                         (self-similar ODE)
===================  ====  =====  =====  =================================

New PDEs register with :func:`register`; see README.md for a walkthrough.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import DerivativeEngine
from repro.core.network import DenseMLP, Network
from repro.core.ntp import MLPParams


class DerivTable:
    """Pointwise derivative lookup handed to ``Operator.residual``.

    ``d(axis, k, comp=c)`` -> (N,) raw k-th pure derivative of component
    ``c`` of u along input ``axis``; ``d.mixed(*axes, comp=c)`` -> (N,)
    mixed partial for an axis tuple the operator declared in
    ``Operator.mixed`` (order within the tuple is irrelevant: partials
    commute for smooth networks).  ``comp`` defaults to 0, so scalar
    residuals never mention it; systems (d_out > 1) address each unknown
    field by its component index.

    ``pure`` is stored with a trailing component axis (d_in, order+1, N,
    d_out); a rank-3 array (the pre-vector layout) is promoted to a single
    component, and mixed entries of shape (N,) likewise.
    """

    def __init__(self, pure: jnp.ndarray,
                 mixed: Dict[Tuple[int, ...], jnp.ndarray] | None = None):
        if pure.ndim == 3:
            pure = pure[..., None]
        self._pure = pure               # (d_in, order+1, N, d_out)
        self._mixed = {k: (v[:, None] if v.ndim == 1 else v)
                       for k, v in (mixed or {}).items()}

    @property
    def n_components(self) -> int:
        return self._pure.shape[-1]

    def _check_comp(self, comp: int) -> None:
        # indices here are Python ints; without this, jnp's clamping
        # semantics would silently serve the last component for an
        # out-of-range comp (wrong physics with green tests)
        if not 0 <= comp < self.n_components:
            raise IndexError(
                f"comp={comp} out of range for a table with "
                f"{self.n_components} component(s)")

    def __call__(self, axis: int, k: int, comp: int = 0) -> jnp.ndarray:
        self._check_comp(comp)
        d_in, orders = self._pure.shape[:2]
        if not (0 <= axis < d_in and 0 <= k < orders):
            raise IndexError(
                f"d(axis={axis}, k={k}) out of range for a table over "
                f"d_in={d_in} axes and orders 0..{orders - 1}")
        return self._pure[axis, k, :, comp]

    def mixed(self, *axes: int, comp: int = 0) -> jnp.ndarray:
        self._check_comp(comp)
        key = tuple(sorted(axes))
        if key not in self._mixed:
            raise KeyError(
                f"mixed partial {key} was not precomputed; declare it in the "
                f"operator's ``mixed=`` field (have: {tuple(self._mixed)})")
        return self._mixed[key][:, comp]


@dataclass(frozen=True)
class Operator:
    """A differential operator with a manufactured/exact solution oracle.

    ``residual(x, d)`` consumes collocation points ``x`` of shape
    (N, d_in) and a :class:`DerivTable`; it returns the pointwise residual --
    (N,) for a single equation, or (n_eq, N) for a multi-equation system
    (one row per equation; losses take the mean square over everything).
    ``d_out`` is the number of unknown field components the residual reads
    from the table (``comp=`` indexing); the solving network must match.
    ``mixed`` lists the axis tuples of every ``d.mixed(...)`` lookup
    the residual performs, so engines can precompute them (one polarization
    batch each).  ``exact(x)`` is the solution the residual vanishes on --
    (N,) for scalar operators, (N, d_out) for systems; it doubles as
    boundary/initial data for training and as the accuracy oracle in tests.
    ``differentiable_exact`` is False when ``exact`` is not a pure
    jax function (e.g. the Burgers profile's bisection inversion), which
    excludes it from autodiff-based oracle checks only.
    """

    name: str
    d_in: int
    order: int
    residual: Callable[[jnp.ndarray, DerivTable], jnp.ndarray]
    exact: Callable[[jnp.ndarray], jnp.ndarray]
    domain: Tuple[Tuple[float, float], ...]
    description: str = ""
    differentiable_exact: bool = True
    mixed: Tuple[Tuple[int, ...], ...] = ()
    d_out: int = 1


_REGISTRY: Dict[str, Operator] = {}


def register(op: Operator) -> Operator:
    if op.name in _REGISTRY:
        raise ValueError(f"operator {op.name!r} already registered")
    if len(op.domain) != op.d_in:
        raise ValueError(f"operator {op.name!r}: domain rank {len(op.domain)} "
                         f"!= d_in {op.d_in}")
    if op.d_out < 1:
        raise ValueError(f"operator {op.name!r}: d_out must be >= 1")
    for axes in op.mixed:
        if any(a < 0 or a >= op.d_in for a in axes):
            raise ValueError(f"operator {op.name!r}: mixed axes {axes} out of "
                             f"range for d_in={op.d_in}")
    _REGISTRY[op.name] = op
    return op


def get_operator(name: str) -> Operator:
    if name not in _REGISTRY:
        raise KeyError(f"unknown operator {name!r}; known: {operator_names()}")
    return _REGISTRY[name]


def operator_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# residual assembly
# ---------------------------------------------------------------------------

def check_net_matches(net: Network, op: Operator) -> None:
    if net.d_out != op.d_out:
        raise ValueError(
            f"operator {op.name!r} solves for {op.d_out} field component(s) "
            f"but the network has d_out={net.d_out}; build the network with "
            f"d_out={op.d_out}")
    if net.d_in != op.d_in:
        raise ValueError(
            f"operator {op.name!r} lives on d_in={op.d_in} coordinates but "
            f"the network has d_in={net.d_in}")


def build_table(net: Network, params, engine: DerivativeEngine,
                op: Operator, x: jnp.ndarray) -> DerivTable:
    """Everything the residual will look up, precomputed in batched engine
    calls: one ``grid`` for pure derivatives plus one polarization ``cross``
    per declared mixed partial.  The component axis rides along for free:
    the grid's trailing ``d_out`` axis becomes the table's ``comp=`` index."""
    check_net_matches(net, op)
    pure = engine.grid(net, params, x, op.order)   # (d_in, n+1, N, d_out)
    mixed = {tuple(sorted(a)): engine.cross(net, params, x, a)   # (N, d_out)
             for a in op.mixed}
    return DerivTable(pure, mixed)


def residual_values(params, op: Operator, x: jnp.ndarray, *,
                    net: Network,
                    engine: Union[str, DerivativeEngine] = "ntp"
                    ) -> jnp.ndarray:
    """Pointwise residual of ``net`` under ``op``: (N,) for single-equation
    operators, (n_eq, N) for systems."""
    eng = DerivativeEngine.from_spec(engine)
    return op.residual(x, build_table(net, params, eng, op, x))


def exact_values(op: Operator, x, dtype=None) -> jnp.ndarray:
    """``op.exact`` normalized to (N, d_out) (exact solutions may be
    numpy-backed and scalar operators return (N,))."""
    vals = jnp.asarray(np.asarray(op.exact(x)))
    if dtype is not None:
        vals = vals.astype(dtype)
    if vals.ndim == 1:
        vals = vals[:, None]
    if vals.shape != (x.shape[0], op.d_out):
        raise ValueError(
            f"operator {op.name!r}: exact() returned shape {vals.shape}, "
            f"want ({x.shape[0]}, {op.d_out})")
    return vals


# ---------------------------------------------------------------------------
# analytic-function oracles (method of manufactured solutions)
# ---------------------------------------------------------------------------

def autodiff_pure_derivs_fn(fn: Callable[[jnp.ndarray], jnp.ndarray],
                            x: jnp.ndarray, order: int) -> jnp.ndarray:
    """(d_in, order+1, N) pure derivatives of any scalar fn((d_in,)) -> ()
    via nested ``jax.grad`` towers -- the oracle path for analytic
    solutions."""
    d = x.shape[-1]

    def one_axis(v):
        def tower(xi):
            h = lambda t: fn(xi + v * t)
            outs = []
            for _ in range(order + 1):
                outs.append(h)
                h = jax.grad(h)
            return jnp.stack([o(jnp.asarray(0.0, x.dtype)) for o in outs])

        return jax.vmap(tower)(x)            # (N, order+1)

    eye = jnp.eye(d, dtype=x.dtype)
    return jnp.transpose(jax.vmap(one_axis)(eye), (0, 2, 1))


def autodiff_mixed_partial_fn(fn: Callable[[jnp.ndarray], jnp.ndarray],
                              x: jnp.ndarray,
                              axes: Tuple[int, ...]) -> jnp.ndarray:
    """(N,) mixed partial of a scalar fn((d_in,)) -> () by direct ``jax.grad``
    nesting along the named coordinates (independent of polarization, so it
    oracles :meth:`DerivativeEngine.cross` too)."""
    g = fn
    for a in axes:
        g = (lambda gg, aa: lambda xi: jax.grad(gg)(xi)[aa])(g, a)
    return jax.vmap(g)(x)


def residual_of_fn(op: Operator, fn: Callable[[jnp.ndarray], jnp.ndarray],
                   x: jnp.ndarray) -> jnp.ndarray:
    """Residual of an arbitrary differentiable function (the MMS oracle:
    ``residual_of_fn(op, exact, x) == 0`` certifies the operator's algebra).

    ``fn`` maps a single point (d_in,) to a scalar for ``d_out == 1``
    operators, or to a (d_out,) vector for systems; each component gets its
    own autodiff tower and the stack fills the table's component axis."""
    comps = [fn] if op.d_out == 1 else \
        [lambda xi, c=c: fn(xi)[c] for c in range(op.d_out)]
    pure = jnp.stack([autodiff_pure_derivs_fn(f, x, op.order)
                      for f in comps], axis=-1)
    mixed = {tuple(sorted(a)):
             jnp.stack([autodiff_mixed_partial_fn(f, x, a) for f in comps],
                       axis=-1)
             for a in op.mixed}
    return op.residual(x, DerivTable(pure, mixed))


def ntp_pure_derivs(params: MLPParams, x: jnp.ndarray, order: int,
                    activation: str = "tanh", impl: str = "jnp") -> jnp.ndarray:
    """(d_in, order+1, N) raw pure derivatives of the network, one jet batch.
    (Legacy surface; ``engine.grid(net, ...)`` is the generic form.)"""
    from repro.core.engines import NTPEngine
    net = DenseMLP.from_params(params, activation)
    return NTPEngine(impl).grid(net, params, x, order)[..., 0]


# ---------------------------------------------------------------------------
# registered operators (coefficients chosen so no term degenerates)
# ---------------------------------------------------------------------------

HEAT_NU = 0.5
WAVE_C = 2.0
KDV_C = 4.0           # soliton speed
AC_EPS = 0.4
_PI = float(np.pi)


def _heat_residual(x, d):
    return d(0, 1) - HEAT_NU * d(1, 2)


def _heat_exact(x):
    return jnp.exp(-HEAT_NU * x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="heat", d_in=2, order=2,
    residual=_heat_residual, exact=_heat_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_t - nu u_xx;  exact u = exp(-nu t) sin x",
))


def _wave_residual(x, d):
    return d(0, 2) - WAVE_C ** 2 * d(1, 2)


def _wave_exact(x):
    return jnp.sin(x[:, 1] - WAVE_C * x[:, 0])


register(Operator(
    name="wave", d_in=2, order=2,
    residual=_wave_residual, exact=_wave_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_tt - c^2 u_xx;  exact u = sin(x - c t)",
))


def _kdv_residual(x, d):
    u = d(0, 0)
    return d(0, 1) + 6.0 * u * d(1, 1) + d(1, 3)


def _kdv_exact(x):
    arg = 0.5 * jnp.sqrt(KDV_C) * (x[:, 1] - KDV_C * x[:, 0])
    return 0.5 * KDV_C / jnp.cosh(arg) ** 2


register(Operator(
    name="kdv", d_in=2, order=3,
    residual=_kdv_residual, exact=_kdv_exact,
    domain=((0.0, 0.4), (-8.0, 8.0)),
    description="u_t + 6 u u_x + u_xxx;  exact single soliton, speed c",
))


def _ac_forcing(x):
    # manufactured solution u* = exp(-t) sin x:
    # u*_t - eps u*_xx + u*^3 - u* = (eps - 2) s + s^3,  s = exp(-t) sin x
    s = jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1])
    return (AC_EPS - 2.0) * s + s ** 3


def _ac_residual(x, d):
    u = d(0, 0)
    return d(0, 1) - AC_EPS * d(1, 2) + u ** 3 - u - _ac_forcing(x)


def _ac_exact(x):
    return jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="allen-cahn", d_in=2, order=2,
    residual=_ac_residual, exact=_ac_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_t - eps u_xx + u^3 - u - f;  manufactured u = exp(-t) sin x",
))


def _poisson_residual(x, d):
    # forcing f = -2 sin x sin y, so u = sin x sin y solves u_xx + u_yy = f
    return d(0, 2) + d(1, 2) + 2.0 * jnp.sin(x[:, 0]) * jnp.sin(x[:, 1])


def _poisson_exact(x):
    return jnp.sin(x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="poisson2d", d_in=2, order=2,
    residual=_poisson_residual, exact=_poisson_exact,
    domain=((0.0, _PI), (0.0, _PI)),
    description="u_xx + u_yy - f;  exact u = sin x sin y (zero on the boundary)",
))


# -- advection-diffusion with a rotated anisotropic diffusion tensor --------
#
# u_t + a . grad u - div(D grad u) = f on (t, x, y), where D = R V R^T with
# rotation R(theta) and principal diffusivities V = diag(nu1, nu2).  In the
# unrotated frame div(D grad u) = d11 u_xx + 2 d12 u_xy + d22 u_yy, so the
# residual has a *genuine mixed-partial term* -- the first registered
# operator to consume polarization (engine.cross / repro.core.ntp.cross).

AD_THETA = _PI / 6.0
AD_NU = (0.3, 0.1)
AD_VEL = (0.7, -0.4)

_c, _s = float(np.cos(AD_THETA)), float(np.sin(AD_THETA))
AD_D11 = AD_NU[0] * _c ** 2 + AD_NU[1] * _s ** 2
AD_D22 = AD_NU[0] * _s ** 2 + AD_NU[1] * _c ** 2
AD_D12 = (AD_NU[0] - AD_NU[1]) * _s * _c


def _ad_exact(x):
    return jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1]) * jnp.sin(x[:, 2])


def _ad_forcing(x):
    # u* = exp(-t) sin x sin y:  u*_t = -u*, u*_xx = u*_yy = -u*,
    # u*_xy = exp(-t) cos x cos y
    e = jnp.exp(-x[:, 0])
    u = e * jnp.sin(x[:, 1]) * jnp.sin(x[:, 2])
    return (-u
            + AD_VEL[0] * e * jnp.cos(x[:, 1]) * jnp.sin(x[:, 2])
            + AD_VEL[1] * e * jnp.sin(x[:, 1]) * jnp.cos(x[:, 2])
            + (AD_D11 + AD_D22) * u
            - 2.0 * AD_D12 * e * jnp.cos(x[:, 1]) * jnp.cos(x[:, 2]))


def _ad_residual(x, d):
    adv = AD_VEL[0] * d(1, 1) + AD_VEL[1] * d(2, 1)
    diff = AD_D11 * d(1, 2) + 2.0 * AD_D12 * d.mixed(1, 2) + AD_D22 * d(2, 2)
    return d(0, 1) + adv - diff - _ad_forcing(x)


register(Operator(
    name="advection-diffusion", d_in=3, order=2,
    residual=_ad_residual, exact=_ad_exact,
    domain=((0.0, 1.0), (-_PI, _PI), (-_PI, _PI)),
    mixed=((1, 2),),
    description="u_t + a.grad u - div(D grad u) - f, D rotated by pi/6 "
                "(cross term 2 d12 u_xy);  manufactured u = exp(-t) sin x sin y",
))


def burgers_operator(lam: float = 0.5, k: int = 1,
                     domain: float = 2.0) -> Operator:
    """Self-similar Burgers profile ODE (paper eq. 7) as a registry operator.

    The specialized trainer (losses.burgers_pinn_loss) keeps its learnable-
    lambda objective; this fixed-lambda form slots the same residual into the
    generic operator surface.  Exact profile inverts X = -U - U^{2k+1} by
    bisection (numpy), hence ``differentiable_exact=False``.
    """
    def residual(x, d):
        u = d(0, 0)
        return -lam * u + ((1.0 + lam) * x[:, 0] + u) * d(0, 1)

    def exact(x):
        from .burgers import exact_profile
        return jnp.asarray(exact_profile(np.asarray(x[:, 0]), k),
                           dtype=x.dtype)

    return Operator(
        name="burgers", d_in=1, order=1, residual=residual, exact=exact,
        domain=((-domain, domain),),
        description="-lam u + ((1+lam) X + u) u';  exact implicit profile",
        differentiable_exact=False,
    )


# -- steady Navier-Stokes in streamfunction-vorticity form ------------------
#
# Eliminating pressure and enforcing incompressibility exactly via the
# streamfunction (u, v) = (psi_y, -psi_x) turns 2-D steady Navier-Stokes
# into ONE scalar 4th-order equation:
#
#     nu lap^2 psi + psi_y d_x(lap psi) - psi_x d_y(lap psi) = f
#
# with lap^2 psi = psi_xxxx + 2 psi_xxyy + psi_yyyy.  The psi_xxyy term is a
# 4th-order mixed partial -- the first consumer of the polarization identity
# beyond order 2 (16 directional order-4 jets); d_x/d_y of the Laplacian add
# third-order mixed terms psi_xyy and psi_xxy (8 order-3 jets each).

NS_NU = 0.5
NS_A = 0.3


def _ns_psi(xi):
    # mixes Laplacian eigenfunctions with different eigenvalues (-2 and -5);
    # a single eigenfunction would make the advection Jacobian
    # J(psi, lap psi) vanish identically and leave the nonlinearity untested
    return (jnp.sin(xi[0]) * jnp.sin(xi[1])
            + NS_A * jnp.sin(2.0 * xi[0]) * jnp.sin(xi[1]))


def _ns_forcing(x):
    # closed-form forcing for psi* = s1 + a s2 with s1 = sin x sin y
    # (lap s1 = -2 s1) and s2 = sin 2x sin y (lap s2 = -5 s2):
    #   lap^2 psi* = 4 s1 + 25 a s2
    #   d_x lap psi* = -2 cos x sin y - 10 a cos 2x sin y
    #   d_y lap psi* = -2 sin x cos y -  5 a sin 2x cos y
    # (kept closed-form -- and params-independent -- so the jitted residual
    # never embeds autodiff towers of the manufactured solution; the MMS
    # test cross-checks this algebra against independent autodiff towers)
    a = NS_A
    sx, cx = jnp.sin(x[:, 0]), jnp.cos(x[:, 0])
    sy, cy = jnp.sin(x[:, 1]), jnp.cos(x[:, 1])
    s2x, c2x = jnp.sin(2.0 * x[:, 0]), jnp.cos(2.0 * x[:, 0])
    psi_x = cx * sy + 2.0 * a * c2x * sy
    psi_y = sx * cy + a * s2x * cy
    lap_x = -2.0 * cx * sy - 10.0 * a * c2x * sy
    lap_y = -2.0 * sx * cy - 5.0 * a * s2x * cy
    bih = 4.0 * sx * sy + 25.0 * a * s2x * sy
    return NS_NU * bih + psi_y * lap_x - psi_x * lap_y


def _ns_residual(x, d):
    psi_x, psi_y = d(0, 1), d(1, 1)
    lap_x = d(0, 3) + d.mixed(0, 1, 1)           # d/dx lap psi
    lap_y = d.mixed(0, 0, 1) + d(1, 3)           # d/dy lap psi
    bih = d(0, 4) + 2.0 * d.mixed(0, 0, 1, 1) + d(1, 4)
    return NS_NU * bih + psi_y * lap_x - psi_x * lap_y - _ns_forcing(x)


def _ns_exact(x):
    return jax.vmap(_ns_psi)(x)


register(Operator(
    name="navier-stokes", d_in=2, order=4,
    residual=_ns_residual, exact=_ns_exact,
    domain=((0.0, _PI), (0.0, _PI)),
    mixed=((0, 0, 1), (0, 1, 1), (0, 0, 1, 1)),
    description="steady Navier-Stokes, streamfunction form: nu lap^2 psi "
                "+ psi_y d_x(lap psi) - psi_x d_y(lap psi) - f;  manufactured "
                "psi = sin x sin y + 0.3 sin 2x sin y",
))


# -- Gray-Scott reaction-diffusion: the first d_out = 2 system --------------
#
#     u_t = Du u_xx - u v^2 + F (1 - u)        + f_u
#     v_t = Dv v_xx + u v^2 - (F + kappa) v    + f_v
#
# on (t, x).  Two coupled unknown fields solved by ONE d_out=2 network; the
# residual reads each component out of the shared derivative table
# (d(axis, k, comp=...)), so both components' derivatives come from the same
# batched jet forwards.  Forcings are manufactured so (u*, v*) below solves
# the system exactly.

GS_DU, GS_DV = 0.16, 0.08
GS_F, GS_KAPPA = 0.9, 0.6


def _gs_exact(x):
    t, s = x[:, 0], x[:, 1]
    u = 1.0 - 0.5 * jnp.exp(-t) * jnp.sin(s)
    v = 0.8 * jnp.exp(-t) * jnp.cos(s)
    return jnp.stack([u, v], axis=-1)


def _gs_forcing(x):
    # u* = 1 - 0.5 e^-t sin x:  u*_t = u*_xx = 0.5 e^-t sin x
    # v* = 0.8 e^-t cos x:      v*_t = v*_xx = -v*
    t, s = x[:, 0], x[:, 1]
    e = jnp.exp(-t)
    u, ut_uxx = 1.0 - 0.5 * e * jnp.sin(s), 0.5 * e * jnp.sin(s)
    v = 0.8 * e * jnp.cos(s)
    f_u = ut_uxx - GS_DU * ut_uxx + u * v ** 2 - GS_F * (1.0 - u)
    f_v = -v + GS_DV * v - u * v ** 2 + (GS_F + GS_KAPPA) * v
    return f_u, f_v


def _gs_residual(x, d):
    u, v = d(0, 0, comp=0), d(0, 0, comp=1)
    f_u, f_v = _gs_forcing(x)
    r_u = (d(0, 1, comp=0) - GS_DU * d(1, 2, comp=0)
           + u * v ** 2 - GS_F * (1.0 - u) - f_u)
    r_v = (d(0, 1, comp=1) - GS_DV * d(1, 2, comp=1)
           - u * v ** 2 + (GS_F + GS_KAPPA) * v - f_v)
    return jnp.stack([r_u, r_v])


register(Operator(
    name="gray-scott", d_in=2, d_out=2, order=2,
    residual=_gs_residual, exact=_gs_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="Gray-Scott reaction-diffusion system (2 coupled fields, "
                "one d_out=2 network);  manufactured u = 1 - 0.5 e^-t sin x, "
                "v = 0.8 e^-t cos x",
))


register(burgers_operator())
