"""Differential-operator subsystem: PDE residuals as jet-primitive compositions.

n-TangentProp turns "evaluate u and its derivatives at collocation points"
into one quasilinear jet forward per direction (core/engines.py).  This
module layers a small abstraction on top so a PDE residual is written ONCE
against a derivative table and runs through every
:class:`repro.core.engines.DerivativeEngine` and every jet-traceable
:class:`repro.core.network.Network`:

* ``residual_values(params, op, x, engine=NTPEngine("pallas"), net=...)`` --
  any engine (ntp jnp/pallas, autodiff baseline, jax.experimental.jet
  oracle) x any network (DenseMLP, MLP, ResidualMLP, FourierFeatureMLP);
* the same residual applied to an *analytic* function via
  :func:`residual_of_fn` -- which is how each operator's manufactured/exact
  solution becomes a test oracle (method of manufactured solutions: the
  residual of the exact solution must vanish identically).

The pre-redesign string keywords (``engine="ntp", impl="pallas",
activation="tanh"`` on a bare ``MLPParams``) still work through
:func:`resolve_net_engine` for one release.

An :class:`Operator` declares its input dimension, the highest pure-
derivative order it consumes, the mixed partials it needs (``mixed``, a
tuple of axis tuples -- served through polarization, ``engine.cross``), a
residual ``R(x, d)`` where ``d(axis, k)`` returns the k-th pure derivative
and ``d.mixed(*axes)`` a declared mixed partial, and an exact solution over
its default domain box.  Registered operators:

===================  ====  =====  ========================================
name                 d_in  order  residual
===================  ====  =====  ========================================
heat                  2     2     u_t - nu u_xx
wave                  2     2     u_tt - c^2 u_xx
kdv                   2     3     u_t + 6 u u_x + u_xxx
allen-cahn            2     2     u_t - eps u_xx + u^3 - u - f(t, x)
poisson2d             2     2     u_xx + u_yy - f(x, y)
advection-diffusion   3     2     u_t + a.grad u - div(D grad u) - f, with
                                  rotated anisotropic D (genuine u_xy term)
burgers               1     1     -lam u + ((1 + lam) x + u) u'  (self-
                                  similar ODE)
===================  ====  =====  ========================================

New PDEs register with :func:`register`; see README.md for a walkthrough.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import DerivativeEngine, resolve_engine
from repro.core.network import DenseMLP, Network
from repro.core.ntp import MLPParams


class DerivTable:
    """Pointwise derivative lookup handed to ``Operator.residual``.

    ``d(axis, k)`` -> (N,) raw k-th pure derivative of u along input ``axis``;
    ``d.mixed(*axes)`` -> (N,) mixed partial for an axis tuple the operator
    declared in ``Operator.mixed`` (order within the tuple is irrelevant:
    partials commute for smooth networks).
    """

    def __init__(self, pure: jnp.ndarray,
                 mixed: Dict[Tuple[int, ...], jnp.ndarray] | None = None):
        self._pure = pure               # (d_in, order+1, N)
        self._mixed = mixed or {}

    def __call__(self, axis: int, k: int) -> jnp.ndarray:
        return self._pure[axis, k]

    def mixed(self, *axes: int) -> jnp.ndarray:
        key = tuple(sorted(axes))
        if key not in self._mixed:
            raise KeyError(
                f"mixed partial {key} was not precomputed; declare it in the "
                f"operator's ``mixed=`` field (have: {tuple(self._mixed)})")
        return self._mixed[key]


@dataclass(frozen=True)
class Operator:
    """A differential operator with a manufactured/exact solution oracle.

    ``residual(x, d)`` consumes collocation points ``x`` of shape
    (N, d_in) and a :class:`DerivTable`; it returns the pointwise residual
    (N,).  ``mixed`` lists the axis tuples of every ``d.mixed(...)`` lookup
    the residual performs, so engines can precompute them (one polarization
    batch each).  ``exact(x)`` is the solution the residual vanishes on; it
    doubles as boundary/initial data for training and as the accuracy oracle
    in tests.  ``differentiable_exact`` is False when ``exact`` is not a pure
    jax function (e.g. the Burgers profile's bisection inversion), which
    excludes it from autodiff-based oracle checks only.
    """

    name: str
    d_in: int
    order: int
    residual: Callable[[jnp.ndarray, DerivTable], jnp.ndarray]
    exact: Callable[[jnp.ndarray], jnp.ndarray]
    domain: Tuple[Tuple[float, float], ...]
    description: str = ""
    differentiable_exact: bool = True
    mixed: Tuple[Tuple[int, ...], ...] = ()


_REGISTRY: Dict[str, Operator] = {}


def register(op: Operator) -> Operator:
    if op.name in _REGISTRY:
        raise ValueError(f"operator {op.name!r} already registered")
    if len(op.domain) != op.d_in:
        raise ValueError(f"operator {op.name!r}: domain rank {len(op.domain)} "
                         f"!= d_in {op.d_in}")
    for axes in op.mixed:
        if any(a < 0 or a >= op.d_in for a in axes):
            raise ValueError(f"operator {op.name!r}: mixed axes {axes} out of "
                             f"range for d_in={op.d_in}")
    _REGISTRY[op.name] = op
    return op


def get_operator(name: str) -> Operator:
    if name not in _REGISTRY:
        raise KeyError(f"unknown operator {name!r}; known: {operator_names()}")
    return _REGISTRY[name]


def operator_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# network/engine resolution (the deprecation shim) and residual assembly
# ---------------------------------------------------------------------------

def resolve_net_engine(params, net: Network | None,
                       engine: Union[str, DerivativeEngine],
                       impl: str | None, activation: str
                       ) -> Tuple[Network, DerivativeEngine]:
    """New-style callers pass ``net=`` + an engine object/spec; old-style
    callers pass a bare ``MLPParams`` with ``engine=``/``impl=``/
    ``activation=`` strings, for which a :class:`DenseMLP` view is
    reconstructed from the parameter shapes."""
    if net is None:
        if not isinstance(params, MLPParams):
            raise TypeError(
                "params is not an MLPParams; pass the owning network via "
                "net= (any repro.core.network.Network)")
        net = DenseMLP.from_params(params, activation)
    return net, resolve_engine(engine, impl)


def _check_scalar(net: Network, what: str) -> None:
    if net.d_out != 1:
        raise ValueError(
            f"{what} consumes a scalar field u (net.d_out == 1); got "
            f"d_out={net.d_out}.  Vector-valued PDE systems need per-"
            "component operators (see ROADMAP).")


def build_table(net: Network, params, engine: DerivativeEngine,
                op: Operator, x: jnp.ndarray) -> DerivTable:
    """Everything the residual will look up, precomputed in batched engine
    calls: one ``grid`` for pure derivatives plus one polarization ``cross``
    per declared mixed partial."""
    _check_scalar(net, f"operator {op.name!r}")
    pure = engine.grid(net, params, x, op.order)[..., 0]     # (d_in, n+1, N)
    mixed = {tuple(sorted(a)): engine.cross(net, params, x, a)[:, 0]
             for a in op.mixed}
    return DerivTable(pure, mixed)


def residual_values(params, op: Operator, x: jnp.ndarray, *,
                    engine: Union[str, DerivativeEngine] = "ntp",
                    activation: str = "tanh", impl: str = "jnp",
                    net: Network | None = None) -> jnp.ndarray:
    """Pointwise residual (N,) of the network under ``op``."""
    net, eng = resolve_net_engine(params, net, engine, impl, activation)
    return op.residual(x, build_table(net, params, eng, op, x))


# ---------------------------------------------------------------------------
# analytic-function oracles (method of manufactured solutions)
# ---------------------------------------------------------------------------

def autodiff_pure_derivs_fn(fn: Callable[[jnp.ndarray], jnp.ndarray],
                            x: jnp.ndarray, order: int) -> jnp.ndarray:
    """(d_in, order+1, N) pure derivatives of any scalar fn((d_in,)) -> ()
    via nested ``jax.grad`` towers -- the oracle path for analytic
    solutions."""
    d = x.shape[-1]

    def one_axis(v):
        def tower(xi):
            h = lambda t: fn(xi + v * t)
            outs = []
            for _ in range(order + 1):
                outs.append(h)
                h = jax.grad(h)
            return jnp.stack([o(jnp.asarray(0.0, x.dtype)) for o in outs])

        return jax.vmap(tower)(x)            # (N, order+1)

    eye = jnp.eye(d, dtype=x.dtype)
    return jnp.transpose(jax.vmap(one_axis)(eye), (0, 2, 1))


def autodiff_mixed_partial_fn(fn: Callable[[jnp.ndarray], jnp.ndarray],
                              x: jnp.ndarray,
                              axes: Tuple[int, ...]) -> jnp.ndarray:
    """(N,) mixed partial of a scalar fn((d_in,)) -> () by direct ``jax.grad``
    nesting along the named coordinates (independent of polarization, so it
    oracles :meth:`DerivativeEngine.cross` too)."""
    g = fn
    for a in axes:
        g = (lambda gg, aa: lambda xi: jax.grad(gg)(xi)[aa])(g, a)
    return jax.vmap(g)(x)


def residual_of_fn(op: Operator, fn: Callable[[jnp.ndarray], jnp.ndarray],
                   x: jnp.ndarray) -> jnp.ndarray:
    """Residual of an arbitrary differentiable scalar function (the MMS oracle:
    ``residual_of_fn(op, exact, x) == 0`` certifies the operator's algebra)."""
    pure = autodiff_pure_derivs_fn(fn, x, op.order)
    mixed = {tuple(sorted(a)): autodiff_mixed_partial_fn(fn, x, a)
             for a in op.mixed}
    return op.residual(x, DerivTable(pure, mixed))


def ntp_pure_derivs(params: MLPParams, x: jnp.ndarray, order: int,
                    activation: str = "tanh", impl: str = "jnp") -> jnp.ndarray:
    """(d_in, order+1, N) raw pure derivatives of the network, one jet batch.
    (Legacy surface; ``engine.grid(net, ...)`` is the generic form.)"""
    from repro.core.engines import NTPEngine
    net = DenseMLP.from_params(params, activation)
    return NTPEngine(impl).grid(net, params, x, order)[..., 0]


# ---------------------------------------------------------------------------
# registered operators (coefficients chosen so no term degenerates)
# ---------------------------------------------------------------------------

HEAT_NU = 0.5
WAVE_C = 2.0
KDV_C = 4.0           # soliton speed
AC_EPS = 0.4
_PI = float(np.pi)


def _heat_residual(x, d):
    return d(0, 1) - HEAT_NU * d(1, 2)


def _heat_exact(x):
    return jnp.exp(-HEAT_NU * x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="heat", d_in=2, order=2,
    residual=_heat_residual, exact=_heat_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_t - nu u_xx;  exact u = exp(-nu t) sin x",
))


def _wave_residual(x, d):
    return d(0, 2) - WAVE_C ** 2 * d(1, 2)


def _wave_exact(x):
    return jnp.sin(x[:, 1] - WAVE_C * x[:, 0])


register(Operator(
    name="wave", d_in=2, order=2,
    residual=_wave_residual, exact=_wave_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_tt - c^2 u_xx;  exact u = sin(x - c t)",
))


def _kdv_residual(x, d):
    u = d(0, 0)
    return d(0, 1) + 6.0 * u * d(1, 1) + d(1, 3)


def _kdv_exact(x):
    arg = 0.5 * jnp.sqrt(KDV_C) * (x[:, 1] - KDV_C * x[:, 0])
    return 0.5 * KDV_C / jnp.cosh(arg) ** 2


register(Operator(
    name="kdv", d_in=2, order=3,
    residual=_kdv_residual, exact=_kdv_exact,
    domain=((0.0, 0.4), (-8.0, 8.0)),
    description="u_t + 6 u u_x + u_xxx;  exact single soliton, speed c",
))


def _ac_forcing(x):
    # manufactured solution u* = exp(-t) sin x:
    # u*_t - eps u*_xx + u*^3 - u* = (eps - 2) s + s^3,  s = exp(-t) sin x
    s = jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1])
    return (AC_EPS - 2.0) * s + s ** 3


def _ac_residual(x, d):
    u = d(0, 0)
    return d(0, 1) - AC_EPS * d(1, 2) + u ** 3 - u - _ac_forcing(x)


def _ac_exact(x):
    return jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="allen-cahn", d_in=2, order=2,
    residual=_ac_residual, exact=_ac_exact,
    domain=((0.0, 1.0), (-_PI, _PI)),
    description="u_t - eps u_xx + u^3 - u - f;  manufactured u = exp(-t) sin x",
))


def _poisson_residual(x, d):
    # forcing f = -2 sin x sin y, so u = sin x sin y solves u_xx + u_yy = f
    return d(0, 2) + d(1, 2) + 2.0 * jnp.sin(x[:, 0]) * jnp.sin(x[:, 1])


def _poisson_exact(x):
    return jnp.sin(x[:, 0]) * jnp.sin(x[:, 1])


register(Operator(
    name="poisson2d", d_in=2, order=2,
    residual=_poisson_residual, exact=_poisson_exact,
    domain=((0.0, _PI), (0.0, _PI)),
    description="u_xx + u_yy - f;  exact u = sin x sin y (zero on the boundary)",
))


# -- advection-diffusion with a rotated anisotropic diffusion tensor --------
#
# u_t + a . grad u - div(D grad u) = f on (t, x, y), where D = R V R^T with
# rotation R(theta) and principal diffusivities V = diag(nu1, nu2).  In the
# unrotated frame div(D grad u) = d11 u_xx + 2 d12 u_xy + d22 u_yy, so the
# residual has a *genuine mixed-partial term* -- the first registered
# operator to consume polarization (engine.cross / repro.core.ntp.cross).

AD_THETA = _PI / 6.0
AD_NU = (0.3, 0.1)
AD_VEL = (0.7, -0.4)

_c, _s = float(np.cos(AD_THETA)), float(np.sin(AD_THETA))
AD_D11 = AD_NU[0] * _c ** 2 + AD_NU[1] * _s ** 2
AD_D22 = AD_NU[0] * _s ** 2 + AD_NU[1] * _c ** 2
AD_D12 = (AD_NU[0] - AD_NU[1]) * _s * _c


def _ad_exact(x):
    return jnp.exp(-x[:, 0]) * jnp.sin(x[:, 1]) * jnp.sin(x[:, 2])


def _ad_forcing(x):
    # u* = exp(-t) sin x sin y:  u*_t = -u*, u*_xx = u*_yy = -u*,
    # u*_xy = exp(-t) cos x cos y
    e = jnp.exp(-x[:, 0])
    u = e * jnp.sin(x[:, 1]) * jnp.sin(x[:, 2])
    return (-u
            + AD_VEL[0] * e * jnp.cos(x[:, 1]) * jnp.sin(x[:, 2])
            + AD_VEL[1] * e * jnp.sin(x[:, 1]) * jnp.cos(x[:, 2])
            + (AD_D11 + AD_D22) * u
            - 2.0 * AD_D12 * e * jnp.cos(x[:, 1]) * jnp.cos(x[:, 2]))


def _ad_residual(x, d):
    adv = AD_VEL[0] * d(1, 1) + AD_VEL[1] * d(2, 1)
    diff = AD_D11 * d(1, 2) + 2.0 * AD_D12 * d.mixed(1, 2) + AD_D22 * d(2, 2)
    return d(0, 1) + adv - diff - _ad_forcing(x)


register(Operator(
    name="advection-diffusion", d_in=3, order=2,
    residual=_ad_residual, exact=_ad_exact,
    domain=((0.0, 1.0), (-_PI, _PI), (-_PI, _PI)),
    mixed=((1, 2),),
    description="u_t + a.grad u - div(D grad u) - f, D rotated by pi/6 "
                "(cross term 2 d12 u_xy);  manufactured u = exp(-t) sin x sin y",
))


def burgers_operator(lam: float = 0.5, k: int = 1,
                     domain: float = 2.0) -> Operator:
    """Self-similar Burgers profile ODE (paper eq. 7) as a registry operator.

    The specialized trainer (losses.burgers_pinn_loss) keeps its learnable-
    lambda objective; this fixed-lambda form slots the same residual into the
    generic operator surface.  Exact profile inverts X = -U - U^{2k+1} by
    bisection (numpy), hence ``differentiable_exact=False``.
    """
    def residual(x, d):
        u = d(0, 0)
        return -lam * u + ((1.0 + lam) * x[:, 0] + u) * d(0, 1)

    def exact(x):
        from .burgers import exact_profile
        return jnp.asarray(exact_profile(np.asarray(x[:, 0]), k),
                           dtype=x.dtype)

    return Operator(
        name="burgers", d_in=1, order=1, residual=residual, exact=exact,
        domain=((-domain, domain),),
        description="-lam u + ((1+lam) X + u) u';  exact implicit profile",
        differentiable_exact=False,
    )


register(burgers_operator())
