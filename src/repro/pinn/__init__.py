"""PINN training framework: self-similar Burgers profiles (paper section IV-C)."""

from .burgers import (exact_profile, lambda_window, profile_lambda,
                      residual_derivs_autodiff, residual_jet, smoothness_order)
from .losses import LossWeights, pinn_loss
from .trainer import PINNResult, PINNRunConfig, train
