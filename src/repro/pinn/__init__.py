"""PINN training framework: differential-operator subsystem (multi-PDE) plus
the paper's self-similar Burgers profiles (section IV-C)."""

from .burgers import (exact_profile, lambda_window, profile_lambda,
                      residual_derivs_autodiff, residual_jet, smoothness_order)
from .losses import (LossWeights, bc_targets, burgers_pinn_loss, pinn_loss,
                     residual_jet_u)
from .operators import (DerivTable, Operator, autodiff_mixed_partial_fn,
                        autodiff_pure_derivs_fn, build_table, burgers_operator,
                        check_net_matches, exact_values, get_operator,
                        ntp_pure_derivs, operator_names, register,
                        residual_of_fn, residual_values)
from .trainer import (OperatorResult, OperatorRunConfig, PINNResult,
                      PINNRunConfig, train, train_operator)
