"""End-to-end PINN training for the self-similar Burgers profiles.

Faithful to the paper's schedule: Adam warm phase, then L-BFGS with strong
Wolfe line search (the forward-pass-heavy phase where n-TangentProp shines).
``engine`` switches the derivative machinery between n-TangentProp and the
nested-autodiff baseline with everything else identical, which is exactly the
comparison in paper Fig. 6.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.engines import DerivativeEngine
from repro.core.network import Network, make_network
from repro.core.ntp import MLPParams, init_mlp, num_params
from repro.data.collocation import (boundary_grid, eval_grid, resample,
                                    sample_box, uniform_grid)
from repro.optim import adam_init, adam_update, lbfgs
from repro.parallel.jet_shard import (ShardedEngine, build_sharded_train_step,
                                      resolve_mesh)

from .burgers import lambda_window, profile_lambda, smoothness_order
from .losses import LossWeights, bc_targets, burgers_pinn_loss, pinn_loss
from .operators import exact_values, get_operator


@dataclass
class PINNRunConfig:
    k: int = 1                      # profile index (lam = 1/2k)
    width: int = 24                 # paper's standard PINN: 3 x 24 tanh
    depth: int = 3
    domain: float = 2.0
    n_domain: int = 512
    n_origin: int = 128
    origin_radius: float = 0.15
    adam_steps: int = 1500
    adam_lr: float = 2e-3
    lbfgs_steps: int = 300
    engine: str = "ntp"             # spec: "ntp" | "ntp/pallas" | "autodiff"
    activation: str = "tanh"
    weights: LossWeights = field(default_factory=LossWeights)
    seed: int = 0
    resample_every: int = 250
    log_every: int = 250


@dataclass
class PINNResult:
    params: MLPParams
    lam: float
    lam_history: List[float]
    loss_history: List[float]
    adam_time_s: float
    lbfgs_time_s: float
    n_params: int
    order: int

    @property
    def lam_error(self) -> float:
        return abs(self.lam - profile_lambda_from_history(self))


def profile_lambda_from_history(res: "PINNResult") -> float:
    # target lam for the profile this run was configured for
    return res._target_lam  # set by train()


def train(cfg: PINNRunConfig) -> PINNResult:
    dtype = jnp.float64
    key = jax.random.PRNGKey(cfg.seed)
    k_init, k_pts = jax.random.split(key)
    params = init_mlp(k_init, 1, cfg.width, cfg.depth, 1, dtype=dtype)
    lam_raw = jnp.zeros((), dtype)
    order = smoothness_order(cfg.k)
    window = lambda_window(cfg.k)
    bc_vals = bc_targets(cfg.k, cfg.domain)

    def loss_fn(ps, pts, origin_pts):
        p, lr = ps
        return burgers_pinn_loss(p, lr, k=cfg.k, pts=pts, origin_pts=origin_pts,
                                 domain=cfg.domain, order=order,
                                 weights=cfg.weights, lam_window=window,
                                 engine=cfg.engine,
                                 activation=cfg.activation, bc_vals=bc_vals)

    # ---------------- Adam phase
    state = adam_init((params, lam_raw))
    pts, origin_pts = resample(k_pts, -cfg.domain, cfg.domain,
                               cfg.n_domain, cfg.n_origin, cfg.origin_radius, dtype)
    lam_hist: List[float] = []
    loss_hist: List[float] = []

    @jax.jit
    def adam_step(ps, state, pts, origin_pts):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            ps, pts, origin_pts)
        ps, state = adam_update(grads, state, ps, cfg.adam_lr)
        return ps, state, loss, aux

    ps = (params, lam_raw)
    t0 = time.perf_counter()
    for step in range(cfg.adam_steps):
        if step and step % cfg.resample_every == 0:
            k_pts, sub = jax.random.split(k_pts)
            pts, origin_pts = resample(sub, -cfg.domain, cfg.domain,
                                       cfg.n_domain, cfg.n_origin,
                                       cfg.origin_radius, dtype)
        ps, state, loss, aux = adam_step(ps, state, pts, origin_pts)
        if step % cfg.log_every == 0 or step == cfg.adam_steps - 1:
            lam_hist.append(float(aux["lambda"]))
            loss_hist.append(float(loss))
    jax.block_until_ready(ps)
    adam_time = time.perf_counter() - t0

    # ---------------- L-BFGS phase (fixed grid, full batch, as in the paper)
    grid = uniform_grid(-cfg.domain, cfg.domain, cfg.n_domain, dtype)
    ogrid = uniform_grid(-cfg.origin_radius, cfg.origin_radius, cfg.n_origin, dtype)
    vg = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

    def vg_flat(ps):
        (loss, aux), grads = vg(ps, grid, ogrid)
        return loss, grads

    t0 = time.perf_counter()
    # the callback samples lambda only: res.loss_history already carries the
    # full per-iteration L-BFGS losses, so appending them here as well would
    # double-count the phase with interleaved every-10th duplicates
    res = lbfgs(vg_flat, ps, steps=cfg.lbfgs_steps,
                callback=lambda it, f, p: (
                    lam_hist.append(float(_lam_of(p[1], window)))
                    if it % 10 == 0 else None))
    lbfgs_time = time.perf_counter() - t0

    params, lam_raw = res.params
    lam = float(_lam_of(lam_raw, window))
    out = PINNResult(params=params, lam=lam, lam_history=lam_hist,
                     loss_history=loss_hist + res.loss_history,
                     adam_time_s=adam_time, lbfgs_time_s=lbfgs_time,
                     n_params=num_params(params), order=order)
    out._target_lam = profile_lambda(cfg.k)
    return out


def _lam_of(lam_raw, window):
    lo, hi = window
    return lo + (hi - lo) * jax.nn.sigmoid(lam_raw)


# ---------------------------------------------------------------------------
# generic operator training (method of manufactured solutions)
# ---------------------------------------------------------------------------

@dataclass
class OperatorRunConfig:
    """Training config for any registered differential operator.

    ``engine`` accepts a spec string ("ntp", "ntp/pallas", "autodiff") or a
    :class:`DerivativeEngine` instance.  ``network`` names a registered
    architecture ("dense", "mlp", "residual", "fourier", "transformer" --
    any composition over the jet-module layer, see ``repro.core.modules``);
    ``net_kwargs`` passes architecture extras (e.g. ``{"n_features": 32}``
    for fourier, ``{"n_heads": 4, "mlp_ratio": 2}`` for transformer, whose
    ``width`` must be divisible by ``n_heads``).  The network's output rank
    follows the operator (``op.d_out``), so multi-equation systems like
    "gray-scott" train with no extra plumbing.
    """

    op: str = "heat"
    width: int = 32
    depth: int = 3
    activation: str = "tanh"
    network: str = "dense"
    net_kwargs: Dict = field(default_factory=dict)
    n_domain: int = 1024
    n_bc: int = 64                  # boundary points per face
    adam_steps: int = 2000
    adam_lr: float = 2e-3
    lbfgs_steps: int = 0
    engine: str = "ntp"             # spec string or DerivativeEngine
    weights: LossWeights = field(default_factory=LossWeights)
    seed: int = 0
    resample_every: int = 500
    log_every: int = 500
    eval_pts_per_axis: int = 48
    # -- multi-device data parallelism (repro.parallel.jet_shard) ----------
    # data_parallel=N shards collocation batches over an (N,)-device "data"
    # mesh (0 = single-device, the default); mesh= passes an explicit mesh
    # carrying a "data" axis instead (e.g. a (4, 2) host mesh).  n_domain
    # must divide the data-axis size.  grad_compression routes the gradient
    # all-reduce through repro.parallel.compression: None (exact fp psum,
    # default), "int8", or "topk:<frac>" -- both with error feedback.
    data_parallel: int = 0
    mesh: Optional[object] = None   # jax.sharding.Mesh (kept untyped: configs
    grad_compression: Optional[str] = None  # import before jax init)


@dataclass
class OperatorResult:
    params: object                  # the network's parameter pytree
    op_name: str
    loss_history: List[float]
    l2_error: float                 # RMS vs the exact solution on a dense grid
    adam_time_s: float
    lbfgs_time_s: float
    n_params: int
    net: Optional[Network] = None


def train_operator(cfg: OperatorRunConfig) -> OperatorResult:
    """Adam (+ optional L-BFGS) on the generic operator objective; the
    operator's exact solution supplies boundary/initial data and the final
    accuracy oracle."""
    op = get_operator(cfg.op)
    dtype = jnp.float64
    key = jax.random.PRNGKey(cfg.seed)
    k_init, k_pts = jax.random.split(key)
    net = make_network(cfg.network, d_in=op.d_in, d_out=op.d_out,
                       width=cfg.width, depth=cfg.depth,
                       activation=cfg.activation, **cfg.net_kwargs)
    engine = DerivativeEngine.from_spec(cfg.engine)
    params = net.init(k_init, dtype=dtype)

    bc_pts = boundary_grid(op.domain, cfg.n_bc, dtype)
    bc_vals = exact_values(op, bc_pts, dtype)

    def make_loss(eng):
        def loss_fn(p, pts):
            return pinn_loss(p, op=op, pts=pts, bc_pts=bc_pts,
                             bc_vals=bc_vals, weights=cfg.weights,
                             engine=eng, net=net)
        return loss_fn

    loss_fn = make_loss(engine)
    mesh = resolve_mesh(cfg.mesh, cfg.data_parallel)
    if mesh is None:
        @jax.jit
        def adam_step(p, state, pts):
            (loss, aux), grads = jax.value_and_grad(loss_fn,
                                                    has_aux=True)(p, pts)
            p, state = adam_update(grads, state, p, cfg.adam_lr)
            return p, state, loss
    else:
        # one shard_map program per step: local loss+grad on each device's
        # collocation shard, psum (optionally compressed) of the grads, and
        # a replicated Adam update -- see repro.parallel.jet_shard
        if cfg.n_domain % mesh.shape["data"]:
            raise ValueError(
                f"n_domain={cfg.n_domain} does not divide the "
                f"{mesh.shape['data']}-way data axis of the mesh")
        built = build_sharded_train_step(
            loss_fn, mesh, adam_lr=cfg.adam_lr,
            compression=cfg.grad_compression)
        ef_err = built.init_err(params)

        def adam_step(p, state, pts):
            nonlocal ef_err
            p, state, (loss, aux), ef_err = built.step(p, state, pts, ef_err)
            return p, state, loss

    state = adam_init(params)
    pts = sample_box(k_pts, op.domain, cfg.n_domain, dtype)
    loss_hist: List[float] = []

    t0 = time.perf_counter()
    for step in range(cfg.adam_steps):
        if step and step % cfg.resample_every == 0:
            k_pts, sub = jax.random.split(k_pts)
            pts = sample_box(sub, op.domain, cfg.n_domain, dtype)
        params, state, loss = adam_step(params, state, pts)
        if step % cfg.log_every == 0 or step == cfg.adam_steps - 1:
            loss_hist.append(float(loss))
    jax.block_until_ready(params)
    adam_time = time.perf_counter() - t0

    lbfgs_time = 0.0
    if cfg.lbfgs_steps > 0:
        grid_pts = sample_box(jax.random.PRNGKey(cfg.seed + 1), op.domain,
                              cfg.n_domain, dtype)
        # under a mesh the full-batch L-BFGS objective shards its grid/cross
        # calls (grads flow through shard_map's transpose); compression is
        # an Adam-phase knob only
        lbfgs_loss = loss_fn if mesh is None \
            else make_loss(ShardedEngine(engine, mesh))
        vg = jax.jit(jax.value_and_grad(lbfgs_loss, has_aux=True))

        def vg_flat(p):
            (loss, aux), grads = vg(p, grid_pts)
            return loss, grads

        t0 = time.perf_counter()
        res = lbfgs(vg_flat, params, steps=cfg.lbfgs_steps)
        lbfgs_time = time.perf_counter() - t0
        params = res.params
        loss_hist.extend(res.loss_history)

    xe = eval_grid(op.domain, cfg.eval_pts_per_axis, dtype)
    u_net = net.apply(params, xe)                   # (N, d_out)
    u_true = exact_values(op, xe, dtype)
    l2 = float(jnp.sqrt(jnp.mean((u_net - u_true) ** 2)))

    return OperatorResult(params=params, op_name=op.name,
                          loss_history=loss_hist, l2_error=l2,
                          adam_time_s=adam_time, lbfgs_time_s=lbfgs_time,
                          n_params=num_params(params), net=net)
