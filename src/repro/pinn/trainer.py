"""End-to-end PINN training for the self-similar Burgers profiles.

Faithful to the paper's schedule: Adam warm phase, then L-BFGS with strong
Wolfe line search (the forward-pass-heavy phase where n-TangentProp shines).
``engine`` switches the derivative machinery between n-TangentProp and the
nested-autodiff baseline with everything else identical, which is exactly the
comparison in paper Fig. 6.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.ntp import MLPParams, init_mlp, num_params
from repro.data.collocation import resample, uniform_grid
from repro.optim import adam_init, adam_update, lbfgs

from .burgers import lambda_window, profile_lambda, smoothness_order
from .losses import LossWeights, bc_targets, pinn_loss


@dataclass
class PINNRunConfig:
    k: int = 1                      # profile index (lam = 1/2k)
    width: int = 24                 # paper's standard PINN: 3 x 24 tanh
    depth: int = 3
    domain: float = 2.0
    n_domain: int = 512
    n_origin: int = 128
    origin_radius: float = 0.15
    adam_steps: int = 1500
    adam_lr: float = 2e-3
    lbfgs_steps: int = 300
    engine: str = "ntp"             # "ntp" | "autodiff"
    impl: str = "jnp"               # "jnp" | "pallas" (ntp only)
    weights: LossWeights = field(default_factory=LossWeights)
    seed: int = 0
    resample_every: int = 250
    log_every: int = 250


@dataclass
class PINNResult:
    params: MLPParams
    lam: float
    lam_history: List[float]
    loss_history: List[float]
    adam_time_s: float
    lbfgs_time_s: float
    n_params: int
    order: int

    @property
    def lam_error(self) -> float:
        return abs(self.lam - profile_lambda_from_history(self))


def profile_lambda_from_history(res: "PINNResult") -> float:
    # target lam for the profile this run was configured for
    return res._target_lam  # set by train()


def train(cfg: PINNRunConfig) -> PINNResult:
    dtype = jnp.float64
    key = jax.random.PRNGKey(cfg.seed)
    k_init, k_pts = jax.random.split(key)
    params = init_mlp(k_init, 1, cfg.width, cfg.depth, 1, dtype=dtype)
    lam_raw = jnp.zeros((), dtype)
    order = smoothness_order(cfg.k)
    window = lambda_window(cfg.k)
    bc_vals = bc_targets(cfg.k, cfg.domain)

    def loss_fn(ps, pts, origin_pts):
        p, lr = ps
        return pinn_loss(p, lr, k=cfg.k, pts=pts, origin_pts=origin_pts,
                         domain=cfg.domain, order=order, weights=cfg.weights,
                         lam_window=window, engine=cfg.engine, impl=cfg.impl,
                         bc_vals=bc_vals)

    vg = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

    # ---------------- Adam phase
    state = adam_init((params, lam_raw))
    pts, origin_pts = resample(k_pts, -cfg.domain, cfg.domain,
                               cfg.n_domain, cfg.n_origin, cfg.origin_radius, dtype)
    lam_hist: List[float] = []
    loss_hist: List[float] = []

    @jax.jit
    def adam_step(ps, state, pts, origin_pts):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            ps, pts, origin_pts)
        ps, state = adam_update(grads, state, ps, cfg.adam_lr)
        return ps, state, loss, aux

    ps = (params, lam_raw)
    t0 = time.perf_counter()
    for step in range(cfg.adam_steps):
        if step and step % cfg.resample_every == 0:
            k_pts, sub = jax.random.split(k_pts)
            pts, origin_pts = resample(sub, -cfg.domain, cfg.domain,
                                       cfg.n_domain, cfg.n_origin,
                                       cfg.origin_radius, dtype)
        ps, state, loss, aux = adam_step(ps, state, pts, origin_pts)
        if step % cfg.log_every == 0 or step == cfg.adam_steps - 1:
            lam_hist.append(float(aux["lambda"]))
            loss_hist.append(float(loss))
    jax.block_until_ready(ps)
    adam_time = time.perf_counter() - t0

    # ---------------- L-BFGS phase (fixed grid, full batch, as in the paper)
    grid = uniform_grid(-cfg.domain, cfg.domain, cfg.n_domain, dtype)
    ogrid = uniform_grid(-cfg.origin_radius, cfg.origin_radius, cfg.n_origin, dtype)

    def vg_flat(ps):
        (loss, aux), grads = vg(ps, grid, ogrid)
        return loss, grads

    t0 = time.perf_counter()
    res = lbfgs(vg_flat, ps, steps=cfg.lbfgs_steps,
                callback=lambda it, f, p: (
                    loss_hist.append(f),
                    lam_hist.append(float(_lam_of(p[1], window)))) if it % 10 == 0 else None)
    lbfgs_time = time.perf_counter() - t0

    params, lam_raw = res.params
    lam = float(_lam_of(lam_raw, window))
    out = PINNResult(params=params, lam=lam, lam_history=lam_hist,
                     loss_history=loss_hist + res.loss_history,
                     adam_time_s=adam_time, lbfgs_time_s=lbfgs_time,
                     n_params=num_params(params), order=order)
    out._target_lam = profile_lambda(cfg.k)
    return out


def _lam_of(lam_raw, window):
    lo, hi = window
    return lo + (hi - lo) * jax.nn.sigmoid(lam_raw)
