"""Gradient compression: int8 quantization with error feedback.

Cross-pod (DCI) bandwidth is the scarcest link in a multi-pod job, so the
pod-level gradient all-reduce is the one worth compressing.  Scheme:

  * per-tensor symmetric int8 quantization (scale = max|g| / 127);
  * error feedback (Karimireddy et al., arXiv:1901.09847): the quantization
    residual is carried into the next step, so the *accumulated* update is
    unbiased and convergence matches fp32 all-reduce asymptotically;
  * the psum itself runs on the int8 payload dequantized locally -- 4x less
    DCI traffic than fp32, 2x less than bf16.

``compressed_psum_tree`` is built on shard_map over the "pod" axis with the
in-pod axes left to GSPMD (auto), matching how launch/train.py composes it.
On meshes without a "pod" axis it degrades to identity (single-pod training
needs no cross-pod reduce).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression of one tensor.

    Returns (int8 payload, scale, new error residual)."""
    corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err.astype(err.dtype)


def ef_init(grads) -> Any:
    """Zero error-feedback buffers shaped like the gradient pytree."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.bfloat16), grads)


def topk_mask(g: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    """Boolean keep-mask of the ``ceil(k_frac * size)`` largest-|g| entries
    (per tensor, at least one entry kept)."""
    if not 0.0 < k_frac <= 1.0:
        raise ValueError(f"k_frac must be in (0, 1], got {k_frac}")
    flat = jnp.abs(g.astype(jnp.float32)).reshape(-1)
    k = max(1, math.ceil(flat.shape[0] * k_frac))
    if k >= flat.shape[0]:
        return jnp.ones(g.shape, bool)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g.astype(jnp.float32)) >= thresh).reshape(g.shape)


def topk_psum_tree(grads, err_tree, axis_name: str = "pod",
                   k_frac: float = 0.1):
    """Magnitude top-k + error-feedback psum of a gradient pytree over
    ``axis_name`` (inside shard_map).

    Each device keeps only the ``k_frac`` largest-magnitude entries of its
    error-corrected gradient (mask chosen locally, so devices keep
    *different* coordinates); dropped mass is carried into the next step's
    residual.  The reduce itself is a dense psum of the sparse-content
    tensors -- on hardware with sparse collectives the payload is the k
    survivors; here the point is the estimator semantics, which the EF
    convergence test pins.  Returns (reduced grads, new error tree)."""

    def one(g, err):
        corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
        keep = topk_mask(corrected, k_frac)
        kept = jnp.where(keep, corrected, 0.0)
        new_err = (corrected - kept).astype(err.dtype)
        total = jax.lax.psum(kept, axis_name)
        return total.astype(g.dtype), new_err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_psum_tree(grads, err_tree, axis_name: str = "pod"):
    """int8+EF psum of a gradient pytree over ``axis_name`` (inside shard_map).

    Returns (reduced fp32-equivalent grads, new error tree)."""

    def one(g, err):
        corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
        # shared scale across pods (one scalar pmax) so the int8 payloads sum
        # exactly: sum_i s*q_i = s * psum(q)
        scale = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name) / 127.0 + 1e-30
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_err = (corrected - q.astype(jnp.float32) * scale).astype(err.dtype)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8 payload
        return (total.astype(jnp.float32) * scale).astype(g.dtype), new_err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, new_e
