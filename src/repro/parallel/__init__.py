"""Distribution utilities: sharded jet computation, gradient compression."""

from .compression import (compressed_psum_tree, dequantize_int8, ef_compress,
                          ef_init, quantize_int8, topk_mask, topk_psum_tree)
from .jet_shard import (DATA_AXIS, ShardedEngine, ShardedTrainStep,
                        build_sharded_train_step, pad_rows, resolve_mesh)
