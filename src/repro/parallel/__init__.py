"""Distribution utilities: gradient compression, elastic helpers."""

from .compression import (compressed_psum_tree, dequantize_int8, ef_compress,
                          ef_init, quantize_int8)
