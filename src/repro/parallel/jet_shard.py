"""Data-parallel jet computation and training over a device mesh.

The paper's quasilinear jet forward is embarrassingly data-parallel over
collocation points: every row of a batched jet is computed independently
(dense layers act row-wise, a transformer's token axis is per-point), and
the jet coefficient axis stays local to each point.  That makes the
multi-device story exact, not approximate:

* :class:`ShardedEngine` wraps any :class:`~repro.core.engines.
  DerivativeEngine` so its ``derivs``/``grid``/``cross`` run under
  ``shard_map`` over the ``"data"`` axis of a mesh -- the batch splits
  across devices, parameters are replicated, and (for the ntp engines)
  the result is **bit-identical** to the single-device call, because every
  device runs exactly the per-row arithmetic the single-device launch
  runs.  Batches that don't divide the mesh are zero-padded up front and
  sliced after (pad rows never reach the caller);
* :func:`build_sharded_train_step` jits one whole data-parallel training
  step -- local loss + grad on each device's shard, a gradient
  all-reduce (plain ``psum`` or the int8 / top-k error-feedback
  compressors from :mod:`repro.parallel.compression`), and a replicated
  Adam update -- as a single ``shard_map`` program, so the collocation
  batch never materializes on one device;
* :func:`resolve_mesh` is the one config knob -> mesh policy shared by
  the trainer, the serving layer, and the example CLIs.

Everything here composes with both engine impls: the Pallas kernels run
per-device inside ``shard_map`` exactly as they do single-device (the
kernel never sees the mesh).  ``check_rep=False`` throughout: the fused
kernels are ``custom_vjp`` ops, which the replication checker cannot see
through.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engines import DerivativeEngine
from repro.core.network import Network

from .compression import compressed_psum_tree, topk_psum_tree

DATA_AXIS = "data"


def resolve_mesh(mesh=None, data_parallel: int = 0,
                 axis: str = DATA_AXIS) -> Optional[jax.sharding.Mesh]:
    """The one knob -> mesh policy: an explicit mesh wins (it must carry the
    data axis), otherwise ``data_parallel=N`` builds a 1-D ``(N,)`` mesh over
    the first N local devices, and 0/None means single-device (no mesh)."""
    if mesh is not None:
        if axis not in mesh.shape:
            raise ValueError(f"mesh {mesh!r} has no {axis!r} axis "
                             f"(axes: {tuple(mesh.shape)})")
        return mesh
    if not data_parallel:
        return None
    n = int(data_parallel)
    if n < 1:
        raise ValueError(f"data_parallel must be >= 1, got {n}")
    if n > jax.device_count():
        raise ValueError(
            f"data_parallel={n} exceeds the {jax.device_count()} visible "
            f"device(s); set XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={n} before importing jax, or lower the knob")
    return jax.make_mesh((n,), (axis,))


def pad_rows(x: jnp.ndarray, multiple: int) -> Tuple[jnp.ndarray, int]:
    """Zero-pad the leading (batch) axis of ``x`` up to a multiple of
    ``multiple``; returns (padded, original row count).  The pad rows are
    well-defined inputs (zeros), compute in parallel with the live rows,
    and are sliced off by the caller -- padding never changes live bits
    because every row of the jet forward is batch-independent."""
    n = x.shape[0]
    if multiple < 1:
        raise ValueError(f"multiple must be >= 1, got {multiple}")
    rem = n % multiple
    if rem == 0:
        return x, n
    pad = jnp.zeros((multiple - rem,) + x.shape[1:], x.dtype)
    return jnp.concatenate([x, pad], axis=0), n


@dataclass(frozen=True)
class ShardedEngine(DerivativeEngine):
    """Run any engine's batched jet calls data-parallel over a mesh.

    Only ``derivs`` is sharded directly; ``grid`` and ``cross`` are
    inherited from the base class, which assembles them from ``derivs`` --
    so the direction tiling happens *before* the shard split and every
    (direction, point) row lands on some device with per-row arithmetic
    identical to the single-device launch.  For the ntp engines that makes
    sharded grid/cross tables bit-identical to unsharded ones (pinned by
    tests/test_jet_shard.py); ``AutodiffEngine`` is vmap-vectorized and
    batch-size-dependent at the last ULP, so parity there is near-exact
    rather than bitwise.

    ``spec`` deliberately reports the INNER engine's spec: the sharded
    engine computes the same mathematical function; the mesh is an
    execution detail (surfaces that must distinguish the two -- e.g. the
    serving executable cache -- key on the mesh shape separately).
    """

    inner: DerivativeEngine
    mesh: jax.sharding.Mesh
    axis: str = DATA_AXIS

    def __post_init__(self):
        if self.axis not in self.mesh.shape:
            raise ValueError(f"mesh has no {self.axis!r} axis "
                             f"(axes: {tuple(self.mesh.shape)})")

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def spec(self) -> str:
        return self.inner.spec

    def derivs(self, net: Network, params, x: jnp.ndarray, order: int,
               tangent: jnp.ndarray | None = None) -> jnp.ndarray:
        if tangent is None:
            tangent = jnp.ones_like(x)
        xp, n = pad_rows(x, self.n_shards)
        vp, _ = pad_rows(tangent, self.n_shards)
        inner, axis = self.inner, self.axis

        f = shard_map(lambda p, xs, vs: inner.derivs(net, p, xs, order, vs),
                      mesh=self.mesh,
                      in_specs=(P(), P(axis), P(axis)),
                      out_specs=P(None, axis, None),
                      check_rep=False)
        return f(params, xp, vp)[:, :n]

    def _batched_directional(self, net: Network, params, x: jnp.ndarray,
                             dirs: jnp.ndarray, order: int) -> jnp.ndarray:
        out = super()._batched_directional(net, params, x, dirs, order)
        # Replicate before grid/cross assembly.  ``derivs`` leaves its output
        # sharded over the tiled (direction x point) batch axis, so the
        # polarization tensordot in ``cross`` would reduce over a
        # device-sharded direction axis -- a cross-device accumulation whose
        # summation order differs from the single-device launch (a 1-ULP
        # f32 diff on 16-term order-4 polarizations).  The all-gather is
        # pure data movement: every value stays bitwise identical, and the
        # reduction then runs with single-device ordering.
        return jax.device_put(
            out, jax.sharding.NamedSharding(self.mesh, P()))


# ---------------------------------------------------------------------------
# whole-step data-parallel training
# ---------------------------------------------------------------------------

def _compressor(compression: Optional[str]) -> Optional[Callable]:
    """Spec string -> (grads, err, axis) -> (reduced grads, new err).

    ``None`` selects the plain fp psum; ``"int8"`` the shared-scale int8
    quantizer; ``"topk:F"`` magnitude top-k keeping fraction F (e.g.
    ``"topk:0.1"``).  Both compressors carry error feedback, so the
    *accumulated* update is unbiased (tested in test_jet_shard.py)."""
    if compression is None:
        return None
    spec = str(compression).strip().lower()
    if spec in ("", "none"):
        return None
    if spec == "int8":
        return compressed_psum_tree
    if spec.startswith("topk:"):
        frac = float(spec.split(":", 1)[1])
        return lambda g, e, ax: topk_psum_tree(g, e, ax, k_frac=frac)
    raise ValueError(f"unknown grad compression {compression!r}; want "
                     "None, 'int8', or 'topk:<frac>' (e.g. 'topk:0.1')")


@dataclass
class ShardedTrainStep:
    """One jitted data-parallel train step plus its error-feedback state
    initializer.  ``step(params, opt_state, pts, err)`` -> ``(params,
    opt_state, (loss, aux), err)``; ``pts`` must divide the data axis."""

    step: Callable
    init_err: Callable
    n_shards: int
    compression: Optional[str]


def build_sharded_train_step(loss_fn: Callable, mesh: jax.sharding.Mesh, *,
                             adam_lr: float, compression: Optional[str] = None,
                             axis: str = DATA_AXIS) -> ShardedTrainStep:
    """Jit one whole data-parallel training step as a ``shard_map`` program.

    ``loss_fn(params, pts) -> (loss, aux)`` is the ordinary single-device
    objective (interior residual mean over ``pts`` plus replicated terms
    such as boundary supervision).  Each device evaluates it on its local
    shard scaled by ``1/n_shards``; summing those local losses over the
    mesh reproduces the global objective exactly (equal shard sizes), so
    ``psum(local grads)`` *is* the global gradient and the replicated Adam
    update stays in lockstep on every device without broadcasting.

    ``compression`` routes the gradient all-reduce through
    :mod:`repro.parallel.compression` (``"int8"`` | ``"topk:F"``, error
    feedback carried in a per-device state tree with a stacked leading
    ``n_shards`` axis).  Off (None) by default: the plain psum path adds no
    approximation whatsoever.
    """
    from repro.optim import adam_update

    comp = _compressor(compression)
    n_sh = mesh.shape[axis]

    def local_step(params, opt_state, pts, err):
        def scaled_loss(p, xs):
            loss, aux = loss_fn(p, xs)
            return loss / n_sh, aux

        (loss, aux), grads = jax.value_and_grad(
            scaled_loss, has_aux=True)(params, pts)
        if comp is None:
            grads = jax.lax.psum(grads, axis)
            new_err = err
        else:
            # err leaves carry a leading stacked device axis outside the
            # shard_map; the local block is (1, *leaf.shape)
            local_err = jax.tree_util.tree_map(lambda e: e[0], err)
            grads, local_err = comp(grads, local_err, axis)
            new_err = jax.tree_util.tree_map(lambda e: e[None], local_err)
        loss = jax.lax.psum(loss, axis)
        aux = jax.tree_util.tree_map(lambda a: jax.lax.psum(a / n_sh, axis),
                                     aux)
        params, opt_state = adam_update(grads, opt_state, params, adam_lr)
        return params, opt_state, (loss, aux), new_err

    sharded = shard_map(local_step, mesh=mesh,
                        in_specs=(P(), P(), P(axis), P(axis)),
                        out_specs=(P(), P(), P(), P(axis)),
                        check_rep=False)

    @jax.jit
    def step(params, opt_state, pts, err):
        if pts.shape[0] % n_sh:
            raise ValueError(f"batch of {pts.shape[0]} rows does not divide "
                             f"the {n_sh}-way data axis; pick n_domain "
                             f"divisible by the mesh")
        return sharded(params, opt_state, pts, err)

    def init_err(params) -> Any:
        """Stacked zero error-feedback buffers, (n_shards, *leaf.shape) per
        leaf -- one residual per device (all-zero when compression is off,
        kept so the step signature is uniform)."""
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_sh,) + p.shape, jnp.bfloat16), params)

    return ShardedTrainStep(step=step, init_err=init_err, n_shards=n_sh,
                            compression=compression)
