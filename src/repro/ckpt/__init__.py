from .manager import CheckpointManager
