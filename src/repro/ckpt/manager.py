"""Checkpointing: atomic, async, elastic.

Design (no orbax dependency -- numpy + json only):
  * a checkpoint is a directory ``step_<N>/`` holding one ``shard_<h>.npz``
    per host (leaf arrays, keyed by flattened pytree path) plus a
    ``manifest.json`` (step, leaf->shard map, tree structure, mesh shape);
  * writes go to ``step_<N>.tmp`` and are atomically renamed -- a crashed
    writer can never corrupt the latest checkpoint (fault tolerance);
  * ``save_async`` hands the host-local arrays to a writer thread so the
    train loop is blocked only for the device->host copy;
  * restore is *elastic*: arrays are loaded by path and device_put against
    whatever shardings the restoring job built -- the mesh may differ from
    the writer's (scale up/down across restarts).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 stale_tmp_age_s: float = 3600.0):
        self.dir = directory
        self.keep = keep
        self.stale_tmp_age_s = stale_tmp_age_s
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._sweep_stale_tmp()

    def _sweep_stale_tmp(self) -> None:
        """Remove ``step_<N>.tmp`` left by a crashed writer -- dead weight
        that ``all_steps`` would otherwise silently skip forever.  Only dirs
        untouched for ``stale_tmp_age_s`` are swept: this manager is not
        necessarily the only writer (e.g. a server constructing a manager
        over a directory a trainer is actively checkpointing into, or
        another process), and a LIVE writer's tmp dir has a fresh mtime --
        every shard/manifest write refreshes it."""
        now = time.time()
        for name in os.listdir(self.dir):
            if not (name.startswith("step_") and name.endswith(".tmp")):
                continue
            path = os.path.join(self.dir, name)
            try:
                age = now - os.path.getmtime(path)
            except OSError:
                continue                 # raced with its writer's rename
            if age >= self.stale_tmp_age_s:
                shutil.rmtree(path, ignore_errors=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        host_arrays = _flatten(tree)  # device->host happens here
        if blocking:
            self._write(step, host_arrays)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_arrays), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, arrays: Dict[str, np.ndarray]) -> None:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):          # crashed writer's leftovers for THIS
            shutil.rmtree(tmp)           # step: clear them however fresh, so
        os.makedirs(tmp)                 # stray files never reach `final`
        np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
        manifest = {"step": step, "time": time.time(),
                    "leaves": sorted(arrays), "n_shards": 1}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        ckpts = self.all_steps()
        for step in ckpts[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{step:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    @staticmethod
    def _check_leaves(step: int, path: str, stored: set, wanted: set) -> None:
        """Fail restore loudly when the checkpoint's leaf set and ``like``'s
        diverge, naming the offending paths (the manifest is authoritative
        when present; the shard keys back it up for pre-manifest dirs)."""
        manifest_path = os.path.join(path, "manifest.json")
        if os.path.exists(manifest_path):
            with open(manifest_path) as f:
                stored = set(json.load(f).get("leaves", stored))
        missing = sorted(wanted - stored)   # in `like`, absent from ckpt
        extra = sorted(stored - wanted)     # in ckpt, absent from `like`
        if missing or extra:
            raise ValueError(
                f"checkpoint step {step} does not match the `like` tree:\n"
                f"  leaves missing from the checkpoint: {missing or 'none'}\n"
                f"  checkpoint leaves absent from `like`: {extra or 'none'}\n"
                f"(checkpoint: {path})")

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Rebuild ``like``-structured pytree; reshard onto ``shardings``
        (elastic: the target mesh may differ from the writer's)."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "shard_0.npz")) as z:
            arrays = {k: z[k] for k in z.files}

        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        flat_keys = list(_flatten(like))
        assert len(flat_keys) == len(leaves_like)
        self._check_leaves(step, path, set(arrays), set(flat_keys))
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            if shardings is not None else [None] * len(leaves_like))
        out = []
        for key, ref, shd in zip(flat_keys, leaves_like, shard_leaves):
            arr = arrays[key].astype(ref.dtype)
            out.append(jax.device_put(arr, shd) if shd is not None
                       else jax.numpy.asarray(arr))
        return treedef.unflatten(out)
