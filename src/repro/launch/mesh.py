"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 chips per pod (v5e); multi-pod adds a 2-pod DCI axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for CPU integration tests (requires host-platform devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
