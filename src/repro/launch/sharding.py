"""Binding logical parameter/input/state specs to a physical mesh, plus the
jitted step builders used by the dry-run, the trainer, and the server.

FSDP: for archs past the threshold, every large parameter additionally
shards its largest still-replicated (and divisible) dimension over the data
axis; XLA inserts the all-gather at use / reduce-scatter at grad time
(GSPMD handles this from the in_shardings alone).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.data.tokens import synthetic_batch
from repro.models import transformer as tfm
from repro.models.sharding_rules import Rules, bind_pspec, make_rules, use_rules
from repro.optim import AdamState, adam_abstract, adam_update

FSDP_PARAM_THRESHOLD = 20_000_000_000  # params; gemma2-27b and llama4 qualify
FSDP_LEAF_MIN = 1 << 22                # don't FSDP tiny leaves


def arch_param_count(cfg: ArchConfig) -> int:
    import math
    params, _ = tfm.init_model(cfg, abstract=True)
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(params))


def wants_fsdp(cfg: ArchConfig) -> bool:
    return arch_param_count(cfg) >= FSDP_PARAM_THRESHOLD


def fsdp_extend(spec: P, shape, rules: Rules, axis_size: int) -> P:
    """Add an "fsdp" entry on the largest unsharded, divisible dim."""
    if not rules.fsdp:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, (e, n) in enumerate(zip(entries, shape)):
        if e is None and n % axis_size == 0 and n > best_size:
            best, best_size = i, n
    if best is None:
        return spec
    entries[best] = "fsdp"
    return P(*entries)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop sharding entries whose dimension doesn't divide the axis size --
    in_shardings (unlike constraints) require exact divisibility.  Keeps the
    framework robust to awkward public configs (granite's 49155 vocab,
    rwkv6's 40 heads)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        size = 1
        for a in axes:
            size *= mesh.shape.get(a, 1)
        out.append(e if size > 0 and dim % size == 0 else None)
    return P(*out)


def bind_param_shardings(mesh, pspecs, abstract_params, rules: Rules):
    axis_size = mesh.shape.get("data", 1)

    import math

    def bind(spec, leaf):
        if rules.fsdp and math.prod(leaf.shape) >= FSDP_LEAF_MIN:
            spec = fsdp_extend(spec, leaf.shape, rules, axis_size)
        bound = bind_pspec(spec, rules)
        return NamedSharding(mesh, sanitize_spec(bound, leaf.shape, mesh))

    return jax.tree_util.tree_map(bind, pspecs, abstract_params)


# ---------------------------------------------------------------------------
# input / state specs
# ---------------------------------------------------------------------------

def batch_pspec(rules: Rules, ndim: int) -> P:
    return P(*((rules.resolve("batch"),) + (None,) * (ndim - 1)))


def input_shardings(mesh, cfg: ArchConfig, shape: ShapeCfg, rules: Rules):
    specs = abstract_inputs(cfg, shape)
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, batch_pspec(rules, len(l.shape))), specs)


def abstract_inputs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    out = {"tokens": jax.ShapeDtypeStruct((b, s - (cfg.vlm_image_tokens or 0)),
                                          jnp.int32)}
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.dtype(cfg.dtype)
    if cfg.encoder is not None:
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder.seq, cfg.d_model), dt)
    if cfg.vlm_image_tokens:
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vlm_image_tokens, tfm.VLM_EMBED_DIM), dt)
    return out


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def state_pspecs(cfg: ArchConfig, shape: ShapeCfg, rules: Rules,
                 mesh) -> Dict[str, Any]:
    """Decode-state sharding: batch over (pod, data) when it divides, else
    sequence-parallel KV (long_500k: B=1 -> shard the 512k cache over data);
    heads/head_dim over model when divisible."""
    from repro.configs.base import MODEL_AXIS
    st = tfm.decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    batch_ax = rules.resolve("batch")
    n_batch = 1
    for a in (rules.batch or ()):
        n_batch *= mesh.shape[a]
    b_entry = batch_ax if _div(shape.global_batch, n_batch) and n_batch > 1 else None
    seq_entry = rules.batch[-1] if (b_entry is None and rules.batch) else None

    def kv_spec(leaf):  # (L, B, S, kvh, hd)
        _, _, s, kvh, hd = leaf.shape
        head_entry = "model" if _div(kvh, MODEL_AXIS) else None
        hd_entry = "model" if (head_entry is None and _div(hd, MODEL_AXIS)) else None
        return P(None, b_entry, seq_entry if _div(s, mesh.shape.get("data", 1)) else None,
                 head_entry, hd_entry)

    out: Dict[str, Any] = {"pos": P()}
    for key in ("kv", "shared_kv", "cross_kv"):
        if key in st:
            out[key] = type(st[key])(*(kv_spec(l) for l in st[key]))
    if "mamba" in st:
        ssm, conv = st["mamba"]
        h = ssm.shape[2]
        out["mamba"] = type(st["mamba"])(
            P(None, b_entry, "model" if _div(h, MODEL_AXIS) else None, None, None),
            P(None, b_entry, None, "model" if _div(conv.shape[-1], MODEL_AXIS) else None))
    if "rwkv" in st:
        wkv, s1, s2 = st["rwkv"]
        h, hd = wkv.shape[2], wkv.shape[3]
        wkv_spec = P(None, b_entry, "model" if _div(h, MODEL_AXIS) else None,
                     None if _div(h, MODEL_AXIS) else ("model" if _div(hd, MODEL_AXIS) else None),
                     None)
        d_spec = P(None, b_entry, None, "model" if _div(s1.shape[-1], MODEL_AXIS) else None)
        out["rwkv"] = type(st["rwkv"])(wkv_spec, d_spec, d_spec)
    return out


def state_shardings(mesh, cfg, shape, rules):
    specs = state_pspecs(cfg, shape, rules, mesh)
    st_abs = tfm.decode_state_specs(cfg, shape.global_batch, shape.seq_len)

    def bind(s, leaf):
        if not isinstance(s, P):
            return s
        return NamedSharding(mesh, sanitize_spec(bind_pspec(s, rules),
                                                 leaf.shape if hasattr(leaf, "shape")
                                                 else (), mesh))

    return jax.tree_util.tree_map(bind, specs, st_abs,
                                  is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuiltStep:
    fn: Any                   # jitted function
    arg_specs: Tuple          # abstract args for .lower()
    rules: Rules
    param_shardings: Any
    opt_state_dtype: Optional[str] = None


DEFAULT_ACCUM_ABOVE = 100_000_000_000  # grad-accum for >100B-param models


def build_train_step(cfg: ArchConfig, mesh, shape: ShapeCfg, *,
                     knobs: tfm.Knobs = tfm.Knobs(),
                     fsdp: Optional[bool] = None,
                     lr: float = 3e-4,
                     accum: Optional[int] = None,
                     policy: str = "tp",
                     opt_state_dtype: Optional[str] = None) -> BuiltStep:
    """jit(train_step) with in/out shardings bound to the mesh."""
    fsdp = wants_fsdp(cfg) if fsdp is None else fsdp
    if accum is None:
        accum = 4 if arch_param_count(cfg) >= DEFAULT_ACCUM_ABOVE else 1
    while shape.global_batch % accum:
        accum //= 2
    rules = make_rules(mesh, fsdp=fsdp, policy=policy)
    abstract_params, pspecs = tfm.init_model(cfg, abstract=True)
    p_shard = bind_param_shardings(mesh, pspecs, abstract_params, rules)
    opt_abs = adam_abstract(abstract_params, opt_state_dtype)
    o_shard = AdamState(NamedSharding(mesh, P()),
                        jax.tree_util.tree_map(
                            lambda s, l: s, p_shard, opt_abs.m),
                        jax.tree_util.tree_map(lambda s, l: s, p_shard, opt_abs.v))
    in_batch = input_shardings(mesh, cfg, shape, rules)

    def grad_fn(params, batch):
        return jax.value_and_grad(tfm.train_loss, has_aux=True)(
            params, cfg, batch, knobs)

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            if accum == 1:
                (loss, metrics), grads = grad_fn(params, batch)
            else:
                from repro.models.sharding_rules import shard as _shard

                def micro(carry, mb):
                    mb = jax.tree_util.tree_map(
                        lambda a: _shard(a, "batch", *([None] * (a.ndim - 1))), mb)
                    (l, m), g = grad_fn(params, mb)
                    gsum, lsum = carry
                    return (jax.tree_util.tree_map(jnp.add, gsum, g),
                            lsum + l), m

                mbs = jax.tree_util.tree_map(
                    lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]),
                    batch)
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), ms = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)), mbs)
                grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
                loss = lsum / accum
                metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)
            new_params, new_opt = adam_update(grads, opt_state, params, lr,
                                              grad_clip=1.0)
            return new_params, new_opt, loss, metrics

    fn = jax.jit(train_step,
                 in_shardings=(p_shard, o_shard, in_batch),
                 out_shardings=(p_shard, o_shard, NamedSharding(mesh, P()),
                                NamedSharding(mesh, P())),
                 donate_argnums=(0, 1))
    args = (abstract_params, opt_abs, abstract_inputs(cfg, shape))
    return BuiltStep(fn, args, rules, p_shard, opt_state_dtype)


def build_prefill_step(cfg: ArchConfig, mesh, shape: ShapeCfg, *,
                       knobs: tfm.Knobs = tfm.Knobs()) -> BuiltStep:
    # >=FSDP-threshold models shard weights over data even at inference
    # (TP-16 alone leaves llama4 at ~50 GiB/chip); all-gather-per-use
    rules = make_rules(mesh, fsdp=wants_fsdp(cfg))
    abstract_params, pspecs = tfm.init_model(cfg, abstract=True)
    p_shard = bind_param_shardings(mesh, pspecs, abstract_params, rules)
    in_batch = input_shardings(mesh, cfg, shape, rules)

    def prefill_step(params, batch):
        with use_rules(rules):
            x, aux, n_prefix, _ = tfm.forward_seq(params, cfg, batch, knobs)
            from repro.models.layers import logits
            return logits(params["embed"], x[:, -1:], cfg)[:, 0]

    fn = jax.jit(prefill_step, in_shardings=(p_shard, in_batch))
    return BuiltStep(fn, (abstract_params, abstract_inputs(cfg, shape)), rules,
                     p_shard)


def build_serve_step(cfg: ArchConfig, mesh, shape: ShapeCfg, *,
                     knobs: tfm.Knobs = tfm.Knobs()) -> BuiltStep:
    """One-token decode against a seq_len-deep cache/state."""
    sp = shape.global_batch == 1
    rules = make_rules(mesh, sp=sp, fsdp=wants_fsdp(cfg))
    abstract_params, pspecs = tfm.init_model(cfg, abstract=True)
    p_shard = bind_param_shardings(mesh, pspecs, abstract_params, rules)
    st_abs = tfm.decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    st_shard = state_shardings(mesh, cfg, shape, rules)
    tok_shard = {"token": NamedSharding(mesh, batch_pspec(rules, 2))} \
        if shape.global_batch > 1 else \
        {"token": NamedSharding(mesh, P(None, None))}

    def serve_step(params, token, state):
        with use_rules(rules):
            return tfm.decode_step(params, cfg, token, state, knobs)

    fn = jax.jit(serve_step,
                 in_shardings=(p_shard, tok_shard["token"], st_shard),
                 out_shardings=(NamedSharding(mesh, P()), st_shard),
                 donate_argnums=(2,))
    args = (abstract_params, abstract_inputs(cfg, shape)["token"], st_abs)
    return BuiltStep(fn, args, rules, p_shard)


def build_step(cfg: ArchConfig, mesh, shape: ShapeCfg, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_serve_step(cfg, mesh, shape, **kw)
