"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input shape x mesh) cell this lowers and compiles
the real step function (train_step / prefill / serve_step) against
ShapeDtypeStruct inputs on a 256-chip single-pod mesh and a 512-chip 2-pod
mesh, prints ``memory_analysis()`` (proves it fits) and ``cost_analysis()``
(feeds section Roofline), and parses collective bytes out of the optimized
HLO.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out-dir benchmarks/results
"""

# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax locks
# the device count at first init, so this must precede every other import.
import os  # noqa: E402

if "--real-devices" not in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512").strip()

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ASSIGNED, SHAPES, get_arch, shape_applicable  # noqa: E402
from repro.launch import hlo_static  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.hlo_analysis import Roofline, model_flops  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.transformer import Knobs  # noqa: E402


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             knobs: Knobs = Knobs(), save_hlo: str | None = None,
             fsdp: bool | None = None, verbose: bool = True,
             policy: str = "tp", attn_repl: bool = False,
             accum: int | None = None, hlo_dir: str | None = None) -> dict:
    cfg = get_arch(arch)
    if attn_repl:
        import dataclasses
        cfg = dataclasses.replace(cfg, attn_sharding="replicate")
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": "pure full-attention arch; see DESIGN.md section 4"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    t0 = time.perf_counter()
    extra = {}
    if shape.kind == "train":
        if fsdp is not None:
            extra["fsdp"] = fsdp
        if accum is not None:
            extra["accum"] = accum
        extra["policy"] = policy
    built = shd.build_step(cfg, mesh, shape, knobs=knobs, **extra)
    with mesh:
        lowered = built.fn.lower(*built.arg_specs)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # static analysis with while-trip-count multiplication (cost_analysis
    # counts loop bodies once -- see launch/hlo_static.py)
    totals = hlo_static.analyze(hlo)

    def _mem(attr):
        return getattr(mem, attr, 0) or 0

    per_dev_bytes = (_mem("argument_size_in_bytes") + _mem("temp_size_in_bytes")
                     + _mem("output_size_in_bytes"))
    rl = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind, n_chips=n_chips,
        hlo_gflops=totals.flops / 1e9,
        hlo_gbytes=totals.bytes / 1e9,
        collective_gbytes=totals.total_collective_bytes / 1e9,
        per_device_mem_gb=per_dev_bytes / 2 ** 30,
        model_gflops=model_flops(cfg, shape, n_chips) / 1e9,
        collectives={**{k: round(v / 1e9, 4) for k, v in
                        totals.collective_bytes.items()},
                     "counts": {k: v for k, v in
                                totals.collective_counts.items()}},
    ).finalize()

    rec = rl.asdict()
    rec.update(lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
               argument_gb=_mem("argument_size_in_bytes") / 2 ** 30,
               temp_gb=_mem("temp_size_in_bytes") / 2 ** 30,
               output_gb=_mem("output_size_in_bytes") / 2 ** 30,
               raw_cost_gflops=float(cost.get("flops", 0)) / 1e9,
               raw_cost_gbytes=float(cost.get("bytes accessed", 0)) / 1e9)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind}] "
              f"compile {t_compile:.0f}s | mem/dev {rl.per_device_mem_gb:.2f} GiB | "
              f"flops {rl.hlo_gflops:.1f}G | bytes {rl.hlo_gbytes:.1f}G | "
              f"coll {rl.collective_gbytes:.3f}G | "
              f"terms c/m/x = {rl.compute_s:.4f}/{rl.memory_s:.4f}/"
              f"{rl.collective_s:.4f}s -> {rl.bottleneck}")
        print(f"  memory_analysis: args={rec['argument_gb']:.2f} "
              f"temp={rec['temp_gb']:.2f} out={rec['output_gb']:.2f} GiB/device")
        print(f"  cost_analysis: flops={rl.hlo_gflops:.2f}G "
              f"bytes={rl.hlo_gbytes:.2f}G useful={rl.useful_fraction:.2f}")
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        with gzip.open(os.path.join(
                hlo_dir, f"{arch}__{shape_name}__{mesh_kind}.hlo.gz"), "wt") as f:
            f.write(hlo)
    return rec


def reanalyze(args) -> int:
    """Recompute roofline JSONs from persisted HLO (analysis-model changes
    don't need a 40-minute recompile sweep)."""
    import gzip

    for name in sorted(os.listdir(args.hlo_dir)):
        if not name.endswith(".hlo.gz"):
            continue
        arch, shape_name, mesh_kind = name[:-7].split("__")[:3]
        with gzip.open(os.path.join(args.hlo_dir, name), "rt") as f:
            hlo = f.read()
        totals = hlo_static.analyze(hlo)
        out_path = os.path.join(args.out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        rec = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                rec = json.load(f)
        cfg = get_arch(arch)
        shape = SHAPES[shape_name]
        n_chips = 512 if mesh_kind == "multi" else 256
        rl = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_kind, n_chips=n_chips,
            hlo_gflops=totals.flops / 1e9,
            hlo_gbytes=totals.bytes / 1e9,
            collective_gbytes=totals.total_collective_bytes / 1e9,
            per_device_mem_gb=rec.get("per_device_mem_gb", 0.0),
            model_gflops=model_flops(cfg, shape, n_chips) / 1e9,
            collectives={**{k: round(v / 1e9, 4) for k, v in
                            totals.collective_bytes.items()},
                         "counts": dict(totals.collective_counts)},
        ).finalize()
        rec.update(rl.asdict())
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"reanalyzed {arch} x {shape_name} x {mesh_kind}: "
              f"m={rl.memory_s:.3f}s x={rl.collective_s:.3f}s -> {rl.bottleneck}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="benchmarks/results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--gla-chunk", type=int, default=64)
    ap.add_argument("--rwkv-chunk", type=int, default=32)
    ap.add_argument("--gla-pair-bf16", action="store_true")
    ap.add_argument("--policy", default="tp", choices=["tp", "dp"])
    ap.add_argument("--attn-repl", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--tag", default="",
                    help="suffix for result filenames (perf iterations)")
    ap.add_argument("--hlo-dir", default=None,
                    help="persist gzipped optimized HLO per cell")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute JSONs from saved HLO (no compile)")
    ap.add_argument("--real-devices", action="store_true",
                    help="skip the 512-device XLA flag (debug)")
    args = ap.parse_args()

    if args.reanalyze:
        return reanalyze(args)

    knobs = Knobs(q_chunk=args.q_chunk, kv_chunk=args.kv_chunk,
                  gla_chunk=args.gla_chunk, rwkv_chunk=args.rwkv_chunk,
                  gla_pair_bf16=args.gla_pair_bf16)
    fsdp = None if args.fsdp is None else (args.fsdp == "on")

    cells = []
    archs = ASSIGNED if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for a, s, m in cells:
        suffix = f"__{args.tag}" if args.tag else ""
        out_path = os.path.join(args.out_dir, f"{a}__{s}__{m}{suffix}.json")
        try:
            rec = run_cell(a, s, m, knobs=knobs, save_hlo=args.save_hlo,
                           fsdp=fsdp, policy=args.policy,
                           attn_repl=args.attn_repl, accum=args.accum,
                           hlo_dir=args.hlo_dir)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "mesh": m, "error": repr(e)}
            failures += 1
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
