"""HLO-level analysis of compiled dry-run artifacts: collective-byte parsing
and the three-term roofline (EXPERIMENTS.md section Roofline).

cost_analysis() provides FLOPs/bytes; collective traffic is parsed from the
optimized HLO text -- every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute operand is sized from its shape string.

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment-fixed).
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128,256]{2,1,0}  or bf16[8]  or f32[] ; tuple types handled by
# scanning every element type in the operand list
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) +
    r")(?:-start|-done)?\(", re.M)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of *result* shape bytes per collective kind.

    For all-reduce result==operand; for all-gather the result is the gathered
    (larger) buffer; for reduce-scatter the operand is larger -- using result
    shapes consistently under-counts RS by the world factor and over-counts
    nothing, keeping the estimate conservative-but-stable across kinds."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        result_type, kind = m.group(1), m.group(2)
        if "-done" in m.group(0):
            continue  # started elsewhere; avoid double count of async pairs
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(result_type))
        out[kind] += nbytes
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_gflops: float            # total FLOPs of the SPMD program (per chip)
    hlo_gbytes: float            # HBM traffic estimate (per chip)
    collective_gbytes: float     # summed collective result bytes (per chip)
    per_device_mem_gb: float     # compiled argument+temp allocation
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_gflops: float = 0.0    # 6*N*D (train) / 2*N*D (inference), active
    useful_fraction: float = 0.0
    collectives: Dict[str, int] = field(default_factory=dict)

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_gflops * 1e9 / PEAK_FLOPS
        self.memory_s = self.hlo_gbytes * 1e9 / HBM_BW
        self.collective_s = self.collective_gbytes * 1e9 / LINK_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        if self.hlo_gflops > 0:
            self.useful_fraction = self.model_gflops / self.hlo_gflops
        return self

    def asdict(self):
        return asdict(self)


def model_flops(cfg, shape, n_chips: int) -> float:
    """Useful-model FLOPs per chip: 6*N_active*D for train, 2*N_active*D for
    inference steps (D = tokens processed per step)."""
    n_active = _active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens / n_chips


def _active_params(cfg) -> float:
    """Parameter count engaged per token (MoE: top_k of n_experts)."""
    from repro.launch.sharding import arch_param_count
    total = arch_param_count(cfg)
    if cfg.moe is None:
        return total
    # split expert weights from the rest analytically
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    n_moe_layers = sum(1 for j in range(cfg.n_layers)
                       if j % cfg.moe.period == cfg.moe.period - 1)
    expert_params = n_moe_layers * e * (cfg.d_model * 2 * cfg.d_ff +
                                        cfg.d_ff * cfg.d_model)
    return (total - expert_params) + expert_params * (k / e)
