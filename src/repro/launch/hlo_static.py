"""Static analysis of post-optimization HLO text with loop-trip-count
multiplication.

``compiled.cost_analysis()`` counts each ``while`` body ONCE -- for
scan-over-layers programs that undercounts FLOPs/bytes/collectives by the
layer count, which would wreck the roofline.  This module parses the
optimized module into per-computation symbol tables (instruction name ->
result shape), computes

  * dot FLOPs: 2 x |result| x prod(lhs contracting dims),
  * HBM bytes: operands + results of materializing ops (fusion boundaries,
    dots, copies, slices, collectives -- the post-fusion buffer model),
  * collective result bytes per kind,

and walks the call graph from ENTRY multiplying ``while`` bodies by their
trip count (recovered from the loop condition's comparison constant -- exact
for lax.scan/fori_loop lowerings).  ``conditional`` takes the max branch.

This is the profile source for the perf loop: no wall clock exists on this
CPU-only container, so the lowered IR *is* the profile.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*->.*\{\s*$")
_OPCODE_RE = re.compile(r"\b([a-z][\w\-]*)\(")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return elems_total, bytes_total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)


def _split_operands(text: str) -> Tuple[List[str], str]:
    """Given text starting at '(' of the op, return (operand names, attrs)."""
    depth = 0
    end = 0
    for i, ch in enumerate(text):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = text[1:end]
    attrs = text[end + 1:]
    names = []
    d = 0
    tok = []
    for ch in inner + ",":
        if ch in "({[":
            d += 1
        elif ch in ")}]":
            d -= 1
        if ch == "," and d == 0:
            t = "".join(tok).strip()
            if t:
                names.append(t.split()[-1])  # last word (may carry a type prefix)
            tok = []
        else:
            tok.append(ch)
    return names, attrs


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None or "= " not in line or not line.startswith("  "):
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.rstrip().endswith("{"):
                nm = hdr.group(1).lstrip("%")
                cur = Computation(nm)
                comps[nm] = cur
                if line.startswith("ENTRY"):
                    entry = nm
                continue
            if cur is not None and line.strip() == "}":
                cur = None
            continue
        m = _INSTR_RE.match(line)
        if m is None or cur is None:
            continue
        name, rhs = m.group(1).lstrip("%"), m.group(2)
        om = _OPCODE_RE.search(rhs)
        if om is None:
            continue
        opcode = om.group(1)
        idx = rhs.find(opcode + "(")
        result_type = rhs[:idx].strip()
        operands, attrs = _split_operands(rhs[idx + len(opcode):])
        operands = [o.lstrip("%") for o in operands]
        ins = Instr(name, opcode, result_type, operands, attrs)
        cur.instrs.append(ins)
        cur.types[name] = result_type
    return comps, entry


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_CONST_RE = re.compile(r"constant\((\d+)\)")
_NAMED_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "cond": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}

# ops whose operands+result count as HBM traffic.  Post-fusion buffer model
# biased toward the TPU target: standalone convert/broadcast/reshape/
# transpose/slice/pad/iota in XLA:CPU output would be fused into consumers by
# XLA:TPU, so they are excluded; what remains is fusion boundaries, matmuls,
# explicit copies/dynamic addressing, reductions and collectives.
_MATERIALIZING = set(("fusion", "dot", "copy", "dynamic-slice",
                      "dynamic-update-slice", "convolution", "gather",
                      "scatter", "sort", "reduce", "reduce-window",
                      "select-and-scatter", "rng-bit-generator",
                      "custom-call") + COLLECTIVE_KINDS)


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)

    def add_collective(self, kind: str, nbytes: float, mult: float):
        self.collective_bytes[kind] = self.collective_bytes.get(kind, 0.0) + nbytes * mult
        self.collective_counts[kind] = self.collective_counts.get(kind, 0.0) + mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def dot_flops(ins: Instr, comp: Computation) -> float:
    res_elems, _ = _shape_elems_bytes(ins.result_type)
    contract = 1
    cm = _CONTRACT_RE.search(ins.attrs)
    if cm and ins.operands:
        lhs_type = comp.types.get(ins.operands[0], "")
        lhs_dims = _shape_dims(lhs_type)
        if cm.group(1):
            for ci in cm.group(1).split(","):
                ci = int(ci)
                if ci < len(lhs_dims):
                    contract *= lhs_dims[ci]
    return 2.0 * res_elems * contract


def while_trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Largest integer constant in the loop condition: exact for the
    ``lt(i, N)`` conditions lax.scan/fori_loop lower to."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" and ins.operands:
            try:
                best = max(best, int(ins.operands[0]))
            except ValueError:
                pass
        for c in _TRIP_CONST_RE.findall(ins.result_type + " " + ins.attrs):
            best = max(best, int(c))
    return best


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    total = 0
    for o in ins.operands:
        t = comp.types.get(o)
        if t:
            total += _shape_elems_bytes(t)[1]
    return total


def analyze(hlo: str) -> Totals:
    comps, entry = parse_computations(hlo)
    totals = Totals()
    if entry is None:
        return totals

    comp_dot_cache: Dict[str, float] = {}

    def comp_dots(name: str) -> float:
        if name not in comp_dot_cache:
            c = comps[name]
            comp_dot_cache[name] = sum(dot_flops(i, c) for i in c.instrs
                                       if i.opcode == "dot")
        return comp_dot_cache[name]

    def walk(comp: Computation, mult: float):
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                b = _NAMED_RE["body"].search(ins.attrs)
                c = _NAMED_RE["cond"].search(ins.attrs)
                trips = while_trip_count(comps, c.group(1)) if c else 1
                if b and b.group(1) in comps:
                    walk(comps[b.group(1)], mult * trips)
                continue
            if op == "conditional":
                br = _NAMED_RE["branches"].search(ins.attrs)
                if br:
                    names = [n.strip().lstrip("%") for n in br.group(1).split(",")
                             if n.strip().lstrip("%") in comps]
                    if names:
                        best = max(names, key=comp_dots)
                        walk(comps[best], mult)
                continue
            if op == "call":
                cm = _NAMED_RE["calls"].search(ins.attrs) or \
                    _NAMED_RE["to_apply"].search(ins.attrs)
                if cm and cm.group(1) in comps:
                    walk(comps[cm.group(1)], mult)
                continue
            if op == "fusion":
                cm = _NAMED_RE["calls"].search(ins.attrs)
                if cm and cm.group(1) in comps:
                    totals.flops += comp_dots(cm.group(1)) * mult
            if op == "dot":
                totals.flops += dot_flops(ins, comp) * mult
            matched_coll = False
            for kind in COLLECTIVE_KINDS:
                if op == kind or op == kind + "-start":
                    totals.add_collective(
                        kind, _shape_elems_bytes(ins.result_type)[1], mult)
                    matched_coll = True
                    break
            base = op[:-6] if op.endswith("-start") else op
            if base in _MATERIALIZING or matched_coll:
                # HBM model: every materialized buffer is written once and
                # read ~once (2x result bytes).  Operand sizes are NOT summed:
                # fusions inside while bodies list whole carried buffers as
                # operands while touching only a slice, which inflates the
                # term by an order of magnitude (measured 12x on rwkv6).
                res_b = _shape_elems_bytes(ins.result_type)[1]
                if base == "dynamic-update-slice":
                    upd = (comp.types.get(ins.operands[1], "")
                           if len(ins.operands) > 1 else "")
                    nbytes = 2 * _shape_elems_bytes(upd)[1]
                elif base == "scatter":
                    upd = (comp.types.get(ins.operands[2], "")
                           if len(ins.operands) > 2 else "")
                    nbytes = 2 * _shape_elems_bytes(upd)[1]
                else:
                    nbytes = 2 * res_b
                totals.bytes += nbytes * mult

    walk(comps[entry], 1.0)
    return totals
