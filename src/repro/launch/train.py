"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 50 --reduced --batch 8 --seq 128

``--reduced`` trains the smoke-scale config on this CPU container; on a real
pod the same driver binds the production mesh.  Wires together: config
registry, synthetic data pipeline, sharded train step, fault-tolerant
Trainer (checkpoint/restart, straggler watchdog), optional n-TangentProp
Sobolev regularization (--ntp-order) -- the paper's technique as a
first-class LM-training feature.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.configs.base import ShapeCfg
from repro.data.tokens import synthetic_batch
from repro.launch.sharding import build_train_step
from repro.models import init_model, train_loss
from repro.models.transformer import Knobs
from repro.optim import adam_init, adam_update
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ntp-order", type=int, default=0,
                    help="add an order-n jet smoothness regularizer (dense archs)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        shape = ShapeCfg("custom", args.seq, args.batch, "train")
    else:
        shape = SHAPES[args.shape]

    key = jax.random.PRNGKey(0)
    params, _ = init_model(cfg, key)
    opt = adam_init(params)

    @jax.jit
    def step_fn(state, batch):
        params, opt = state
        def loss_fn(p):
            loss, metrics = train_loss(p, cfg, batch)
            if args.ntp_order > 0:
                from repro.launch.ntp_reg import ntp_smoothness
                loss = loss + 1e-4 * ntp_smoothness(p, cfg, batch, args.ntp_order)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(grads, opt, params, args.lr, grad_clip=1.0)
        return (params, opt), loss

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir),
        step_fn,
        lambda step: synthetic_batch(cfg, shape, step),
        straggler_cb=lambda s, dt, ema: print(f"[straggler] step {s}: {dt:.2f}s vs ema {ema:.2f}s"),
    )
    t0 = time.perf_counter()
    (params, opt), report = trainer.run((params, opt))
    dt = time.perf_counter() - t0
    print(f"ran {report.steps_run} steps in {dt:.1f}s "
          f"({report.restarts} restarts, {report.stragglers} stragglers)")
    print("loss first->last:", report.losses[0], "->", report.losses[-1])


if __name__ == "__main__":
    main()
