"""Batched serving driver: prefill + decode loop with the KV/state machinery.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Serving realism on this CPU container is at reduced scale; the production
decode path (ring-buffer caches, recurrent states, sharded serve_step) is the
same code the decode_32k / long_500k dry-run cells lower.  ``--reduced`` and
``--greedy`` default on and are disabled with ``--no-reduced`` /
``--no-greedy`` (non-greedy decode samples from the softmax with a fixed
seed).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import synthetic_batch
from repro.configs.base import ShapeCfg
from repro.models import (decode_state_specs, decode_step, init_model, prefill)


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--greedy", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="argmax decode; --no-greedy samples from the "
                         "logits (seeded)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="PRNG seed for --no-greedy sampling")
    args = ap.parse_args(argv)
    if args.prompt_len < 1:
        # the first generated token conditions on the last prompt logit; an
        # empty prompt would leave the SSM warm-up loop with logits=None
        # (and the attention prefill with nothing to prefill)
        ap.error("--prompt-len must be >= 1: decode is seeded from the last "
                 "prompt position's logits")
    if args.gen < 1:
        ap.error("--gen must be >= 1")
    return args


def select_token(logits: jnp.ndarray, *, greedy: bool,
                 key: jax.Array | None = None) -> jnp.ndarray:
    """Next-token choice from (batch, vocab) logits: argmax when greedy,
    seeded categorical sampling otherwise.  Returns (batch, 1) int32."""
    if greedy:
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    if key is None:
        raise ValueError("non-greedy decoding needs a PRNG key")
    return jax.random.categorical(key, logits, axis=-1)[:, None] \
        .astype(jnp.int32)


def main(argv=None) -> None:
    args = parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params, _ = init_model(cfg, key)
    sample_key = jax.random.PRNGKey(args.sample_seed)

    shape = ShapeCfg("serve", args.prompt_len, args.batch, "prefill")
    batch = synthetic_batch(cfg, shape, 0)
    cap = args.prompt_len + args.gen + (cfg.vlm_image_tokens or 0)

    step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))

    t0 = time.perf_counter()
    if cfg.block_type == "attn":
        logits, st = prefill(params, cfg, batch, pad_to=cap)
    else:
        # SSM-family: warm the recurrent state token by token (prompt_len
        # >= 1 is enforced at parse time, so logits is always bound here)
        st = decode_state_specs(cfg, args.batch, cap, abstract=False)
        st["pos"] = jnp.asarray(0, jnp.int32)
        logits = None
        for t in range(args.prompt_len):
            logits, st = step(params, batch["tokens"][:, t:t + 1], st)
    t_prefill = time.perf_counter() - t0

    sample_key, sub = jax.random.split(sample_key)
    tok = select_token(logits, greedy=args.greedy, key=sub)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, st = step(params, tok, st)
        sample_key, sub = jax.random.split(sample_key)
        tok = select_token(logits, greedy=args.greedy, key=sub)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} greedy={args.greedy}")
    print(f"prefill {t_prefill*1e3:.1f} ms | decode {t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.gen-1,1)*1e3:.2f} ms/token)")
    print("sample generations:", gen[:2].tolist())


if __name__ == "__main__":
    main()
