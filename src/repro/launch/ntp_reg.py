"""n-TangentProp as a first-class LM-training feature: jet smoothness
regularization of a dense transformer w.r.t. its input embeddings.

TangentProp's original use was penalizing first derivatives along invariance
directions; the quasilinear n-jet makes arbitrary-order Sobolev penalties
affordable for transformers.  This propagates an exact order-n Taylor jet of
the *whole dense block stack* (RMSNorm -> GQA attention with softmax -> GeGLU/
SwiGLU) along a random embedding-space direction and penalizes the top
coefficient's norm -- all through core/jet.py rules (DESIGN.md section 2,
"beyond the paper").

Cost control: the jet rides a token slice (first ``reg_tokens`` positions)
and full (unblocked) attention -- the regularizer is O(order^2) small
matmuls on a short sequence, negligible next to the main loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import jet as J
from repro.models.layers import embed
from repro.models.transformer import _is_moe, _pattern_at

REG_TOKENS = 64


def _jet_rope(x: J.Jet, positions, theta: float) -> J.Jet:
    from repro.models.layers import rope
    return J.jmap(lambda c: rope(c, positions, theta), x)


def _jet_attn(lp, cfg: ArchConfig, x: J.Jet, window) -> J.Jet:
    s = x.shape[-2]
    pos = jnp.arange(s)
    q = J.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = J.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = J.einsum("bsd,dhk->bshk", x, lp["wv"])
    if "q_norm" in lp:
        q = J.rms_norm(q, 1.0 + lp["q_norm"], offset=0.0)
        k = J.rms_norm(k, 1.0 + lp["k_norm"], offset=0.0)
    q = _jet_rope(q, pos, cfg.rope_theta)
    k = _jet_rope(k, pos, cfg.rope_theta)
    kvh, hd = lp["wk"].shape[1], lp["wk"].shape[2]
    g = cfg.n_heads // kvh
    qg = J.jmap(lambda c: c.reshape(c.shape[0], s, kvh, g, hd), q)
    scores = J.scale(J.einsum("bqhgd,bkhd->bhgqk", qg, k), hd ** -0.5)
    if cfg.attn_softcap:
        scores = J.scale(J.tanh(J.scale(scores, 1.0 / cfg.attn_softcap)),
                         cfg.attn_softcap)
    iq = jnp.arange(s)[:, None]
    ik = jnp.arange(s)[None, :]
    mask = ik <= iq
    if window is not None:
        mask &= ik > iq - window
    scores = J.where(mask, scores, J.const(jnp.full((), -2e38, scores.dtype),
                                           scores.order, like=scores))
    probs = J.softmax(scores, axis=-1)
    out = J.einsum("bhgqk,bkhd->bqhgd", probs, v)
    out = J.jmap(lambda c: c.reshape(c.shape[0], s, kvh * g, hd), out)
    return J.einsum("bshk,hkd->bsd", out, lp["wo"])


def _jet_mlp(lp, cfg: ArchConfig, x: J.Jet) -> J.Jet:
    if cfg.mlp in ("swiglu", "geglu"):
        gu = J.einsum("bsd,dtf->bstf", x, lp["wi"])
        gate = J.jmap(lambda c: c[..., 0, :], gu)
        up = J.jmap(lambda c: c[..., 1, :], gu)
        act = J.silu(gate) if cfg.mlp == "swiglu" else J.gelu(gate)
        return J.einsum("bsf,fd->bsd", J.mul(act, up), lp["wo"])
    if cfg.mlp == "gelu_mlp":
        return J.einsum("bsf,fd->bsd", J.gelu(J.einsum("bsd,df->bsf", x, lp["wi"])),
                        lp["wo"])
    raise NotImplementedError(cfg.mlp)


def jet_forward_dense(params, cfg: ArchConfig, tokens: jnp.ndarray,
                      order: int, direction: jnp.ndarray | None = None) -> J.Jet:
    """Order-n jet of final hidden states along an embedding direction.

    Dense attention stacks only (DESIGN.md section 4 applicability table)."""
    if cfg.block_type != "attn" or cfg.moe is not None:
        raise NotImplementedError("jet regularizer: dense attention archs only")
    ct = (jnp.float64 if params["final_norm"].dtype == jnp.float64
          else jnp.float32)  # compute dtype follows params (tests run f64)
    x0 = embed(params["embed"], tokens, cfg).astype(ct)
    if direction is None:
        direction = jnp.sign(jnp.sin(jnp.arange(x0.size, dtype=ct)
                                     )).reshape(x0.shape) * (x0.shape[-1] ** -0.5)
    x = J.seed(x0, direction.astype(x0.dtype), order)

    g = cfg.group
    layers = params["stack"]["groups"]["layers"]
    n_groups = cfg.n_layers // g

    def group_body(coeffs, gparams):
        x = J.Jet(coeffs)
        for j in range(g):
            lp = gparams["layers"][j]
            window = cfg.window if _pattern_at(cfg, j) == "local" else None
            h = J.rms_norm(x, lp["ln1"].astype(ct), offset=1.0)
            x = J.add(x, _jet_attn(_f32(lp["attn"], ct), cfg, h, window))
            h = J.rms_norm(x, lp["ln2"].astype(ct), offset=1.0)
            x = J.add(x, _jet_mlp(_f32(lp["ffn"], ct), cfg, h))
        return x.coeffs, None

    coeffs, _ = jax.lax.scan(group_body, x.coeffs,
                             {"layers": _f32(layers, ct)})
    x = J.Jet(coeffs)
    for r, lp in enumerate(params["stack"]["rest"]):
        window = cfg.window if _pattern_at(cfg, n_groups * g + r) == "local" else None
        h = J.rms_norm(x, lp["ln1"].astype(ct), offset=1.0)
        x = J.add(x, _jet_attn(_f32(lp["attn"], ct), cfg, h, window))
        h = J.rms_norm(x, lp["ln2"].astype(ct), offset=1.0)
        x = J.add(x, _jet_mlp(_f32(lp["ffn"], ct), cfg, h))
    return J.rms_norm(x, params["final_norm"].astype(ct), offset=1.0)


def _f32(tree, ct=jnp.float32):
    return jax.tree_util.tree_map(lambda a: a.astype(ct), tree)


def ntp_smoothness(params, cfg: ArchConfig, batch, order: int) -> jnp.ndarray:
    """Mean squared top Taylor coefficient of the hidden states: an exact
    order-n Sobolev penalty, one quasilinear forward."""
    tokens = batch["tokens"][:, :REG_TOKENS]
    jet = jet_forward_dense(params, cfg, tokens, order)
    return jnp.mean(jet.coeffs[order] ** 2)
