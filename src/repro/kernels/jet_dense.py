"""Pallas TPU kernel: fused n-TangentProp dense layer (MXU + VPU).

One layer of the paper's Algorithm 1 is ``jet -> W @ jet + b -> tanh-jet``.
Done naively that is two HBM round-trips for the ``(n+1, B, D)`` stack (GEMM
out, activation in).  This kernel fuses them:

  * the coefficient axis is folded into the GEMM M-dimension -- each block
    computes ``((n+1)*block_b, block_k) @ (block_k, block_d)`` on the MXU,
    so the derivative stack *rides the systolic array* instead of issuing
    (n+1) strided small matmuls;
  * K is the innermost (``arbitrary``) grid axis accumulating into a VMEM
    f32 scratch; on the last K step the Faa di Bruno epilogue (tanh_jet.py's
    ``act_jet_body``) runs in-register and writes the activated jet once.

Block shapes are chosen for the v5e MXU/VPU: ``block_k = block_d = 128``
multiples (lane dim), ``block_b`` a multiple of 8 (sublane).  bf16/f32 inputs
accumulate in f32 (``preferred_element_type``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tanh_jet import act_jet_body


def _kernel(y_ref, w_ref, b_ref, o_ref, acc_ref, *, activation, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    y = y_ref[...]                       # (n+1, bb, bk)
    n1, bb, bk = y.shape
    w = w_ref[...]                       # (bk, bd)
    part = jnp.dot(y.reshape(n1 * bb, bk), w,
                   preferred_element_type=acc_ref.dtype)
    acc_ref[...] += part.reshape(n1, bb, -1)

    @pl.when(k == n_k - 1)
    def _epilogue():
        z = acc_ref[...]
        z = z.at[0].add(b_ref[...].astype(acc_ref.dtype)[0])
        if activation is None:
            o_ref[...] = z.astype(o_ref.dtype)
        else:
            o_ref[...] = act_jet_body(z, activation).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "block_b", "block_k",
                                             "block_d", "interpret"))
def jet_dense_pallas(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                     activation: str | None = "tanh",
                     block_b: int = 128, block_k: int = 128, block_d: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    """(n+1, B, Din) x (Din, Dout) -> activated jet (n+1, B, Dout)."""
    n1, bsz, din = coeffs.shape
    dout = w.shape[1]
    bb, bk, bd = min(block_b, bsz), min(block_k, din), min(block_d, dout)
    pb, pk, pd = (-bsz) % bb, (-din) % bk, (-dout) % bd

    y = jnp.pad(coeffs, ((0, 0), (0, pb), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pd)))
    bp = jnp.pad(b, ((0, pd),)).reshape(1, -1)

    grid = (y.shape[1] // bb, wp.shape[1] // bd, wp.shape[0] // bk)
    n_k = grid[2]

    try:  # dimension semantics: parallel over (B, Dout), sequential over K
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except AttributeError:  # older jax
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1, bb, bk), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bd), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((n1, bb, bd), lambda i, j, k: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((n1, y.shape[1], wp.shape[1]), coeffs.dtype),
        # f32 accumulation for the TPU-realistic dtypes (f32/bf16 in); f64
        # inputs -- the interpret-mode oracle tests -- accumulate in f64
        scratch_shapes=[pltpu.VMEM((n1, bb, bd),
                                   jnp.promote_types(coeffs.dtype,
                                                     jnp.float32))],
        compiler_params=compiler_params,
        interpret=interpret,
    )(y, wp, bp)
    return out[:, :bsz, :dout]
