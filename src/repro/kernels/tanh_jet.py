"""Pallas TPU kernel: fused Faa di Bruno activation jet (pointwise, VPU).

Input is the scaled-Taylor coefficient stack of the pre-activations,
``(n+1, B, W)``.  One VMEM round-trip computes the full activation jet:

  1. ``u = tanh(c_0)``                       (one transcendental per element)
  2. ``F_m = P_m(u)``                        (static Horner chains, m = 0..n)
  3. ``out_k = sum_{p in P(k)} C_p F_|p| prod_j c_j^{p_j}``
                                             (static partition contraction)

All tables are Python immediates (kernels/bell_tables.py) so the body is pure
FMA/VPU work; there is no gather, no control flow, and the (n+1) coefficient
axis lives entirely in VMEM for the tile.  Tiling: ``(n+1, block_b, block_w)``
blocks over a ``(B/block_b, W/block_w)`` grid -- the coefficient axis is never
split because order k mixes all lower orders.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import math

from .bell_tables import fdb_terms, sigmoid_poly_rows, tanh_poly_rows

_POLY_ROWS = {"tanh": tanh_poly_rows, "sigmoid": sigmoid_poly_rows}
KERNEL_ACTS = ("tanh", "sigmoid", "sin")


def _horner(row, u):
    acc = jnp.full_like(u, row[-1])
    for c in row[-2::-1]:
        acc = acc * u + c
    return acc


def _taylor_stack(z0: jnp.ndarray, n: int, activation: str) -> list:
    """F_m = sigma^(m)(z0)/m! for m = 0..n, as pure VPU work.

    tanh/sigmoid evaluate one transcendental then static Horner chains in it;
    sin cycles sigma^(m)(a) = sin(a + m pi/2) through two transcendentals and
    sign flips (the SIREN / Fourier-feature trunk activation)."""
    if activation == "sin":
        s, c = jnp.sin(z0), jnp.cos(z0)
        cycle = (s, c, -s, -c)
        return [cycle[m % 4] * (1.0 / math.factorial(m)) for m in range(n + 1)]
    if activation == "tanh":
        u = jnp.tanh(z0)
    elif activation == "sigmoid":
        u = 0.5 * (jnp.tanh(0.5 * z0) + 1.0)
    else:
        raise ValueError(activation)
    rows_tab = _POLY_ROWS[activation](n)
    return [_horner(rows_tab[m], u) for m in range(n + 1)]


def act_jet_body(z: jnp.ndarray, activation: str) -> jnp.ndarray:
    """The jet epilogue on an in-register/in-VMEM stack ``z`` of shape (n+1, ...).

    Shared by this kernel and jet_dense's epilogue so both are tested by the
    same sweeps."""
    n = z.shape[0] - 1
    f = _taylor_stack(z[0], n, activation)
    out = [f[0]]
    for k, terms in enumerate(fdb_terms(n), start=1):
        acc = None
        for coef, m, powers in terms:
            prod = f[m] * coef
            for j, e in powers:
                zj = z[j]
                for _ in range(e):
                    prod = prod * zj
            acc = prod if acc is None else acc + prod
        out.append(acc)
    return jnp.stack(out)


def _kernel(y_ref, o_ref, *, activation: str):
    o_ref[...] = act_jet_body(y_ref[...], activation)


@functools.partial(jax.jit, static_argnames=("activation", "block_b", "block_w", "interpret"))
def act_jet_pallas(coeffs: jnp.ndarray, activation: str = "tanh",
                   block_b: int = 256, block_w: int = 256,
                   interpret: bool = True) -> jnp.ndarray:
    """coeffs: (n+1, B, W) -> activation jet of the same shape."""
    n1, b, w = coeffs.shape
    bb, bw = min(block_b, b), min(block_w, w)
    pb, pw = (-b) % bb, (-w) % bw
    padded = jnp.pad(coeffs, ((0, 0), (0, pb), (0, pw)))
    grid = (padded.shape[1] // bb, padded.shape[2] // bw)
    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[pl.BlockSpec((n1, bb, bw), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((n1, bb, bw), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, coeffs.dtype),
        interpret=interpret,
    )(padded)
    return out[:, :b, :w]
