"""Pallas TPU kernels for the n-TangentProp hot path.

The paper's compute hot-spot is the per-layer jet propagation (stacked GEMM +
Faa di Bruno activation contraction); ``jet_dense`` fuses both into one VMEM
round-trip, ``act_jet`` is the standalone pointwise epilogue.  ``ref.py``
holds the pure-jnp oracles the test sweeps compare against.
"""

from . import ops, ref
from .ops import act_jet, jet_dense
