"""Pallas TPU kernels for the n-TangentProp hot path.

The paper's compute hot-spot is the per-layer jet propagation (stacked GEMM +
Faa di Bruno activation contraction); ``jet_dense`` fuses both into one VMEM
round-trip, ``act_jet`` is the standalone pointwise epilogue.  The
transformer trunk adds ``jet_attention_scores`` (Cauchy-product QK^T + scale
+ softmax recurrence, one launch per attention layer) and ``jet_rms_norm``
(mean-square convolution + rsqrt recurrence + gain).  ``ref.py`` holds the
pure-jnp oracles the test sweeps compare against.
"""

from . import ops, ref
from .ops import act_jet, jet_attention_scores, jet_dense, jet_rms_norm
