"""Pallas TPU kernels for the n-TangentProp hot path.

The paper's compute hot-spot is the per-layer jet propagation (stacked GEMM +
Faa di Bruno activation contraction); ``jet_dense`` fuses both into one VMEM
round-trip, ``act_jet`` is the standalone pointwise epilogue.  The
transformer trunk runs ``jet_flash_attention`` -- the WHOLE attention layer
(score Cauchy product, tiled online-softmax jet recurrence, value
contraction, output projection) in a single launch whose working set is
bounded by its block sizes, never the materialized (T, T) score jet -- and
``jet_rms_norm`` (mean-square convolution + rsqrt recurrence + gain).
``jet_attention_scores`` is the PR-5 materializing score kernel, kept for
benchmarking against.  ``ref.py`` holds the pure-jnp oracles the test sweeps
compare against; ``ops.epilogues()`` is the typed registry modules consult
before dispatching here.
"""

from . import ops, ref
from .ops import (EpilogueKind, act_jet, epilogues, jet_attention_scores,
                  jet_dense, jet_flash_attention, jet_rms_norm)
