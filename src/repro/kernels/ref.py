"""Pure-jnp oracles for the Pallas kernels.

These are *independent* straight-line implementations (no Pallas, no
core.jet reuse beyond the static tables) so kernel bugs cannot hide behind a
shared code path.  Tests sweep shapes/dtypes and assert allclose against
these.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.activations import sin_taylor_stack

from .bell_tables import fdb_terms, sigmoid_poly_rows, tanh_poly_rows

_POLY_ROWS = {"tanh": tanh_poly_rows, "sigmoid": sigmoid_poly_rows}
_PRIMAL = {"tanh": jnp.tanh, "sigmoid": lambda a: 0.5 * (jnp.tanh(0.5 * a) + 1.0)}


def _taylor_stack(a: jnp.ndarray, n: int, activation: str) -> list[jnp.ndarray]:
    """[sigma^(m)(a)/m! for m in 0..n] via Horner on the closed-form polys
    (tanh/sigmoid) or core.activations' sin phase cycle (same closed form the
    in-kernel stack hardcodes; only the polynomial tables stay independent)."""
    if activation == "sin":
        return list(sin_taylor_stack(a, n))
    u = _PRIMAL[activation](a)
    rows = _POLY_ROWS[activation](n)
    out = []
    for m in range(n + 1):
        row = rows[m]
        acc = jnp.full_like(u, row[-1])
        for c in row[-2::-1]:
            acc = acc * u + c
        out.append(acc)
    return out


def act_jet_ref(coeffs: jnp.ndarray, activation: str = "tanh") -> jnp.ndarray:
    """Faa di Bruno activation jet.  coeffs: (n+1, ...) scaled Taylor coeffs of
    the pre-activation; returns the same-shaped stack for sigma(pre-act)."""
    n = coeffs.shape[0] - 1
    f = _taylor_stack(coeffs[0], n, activation)
    rows = [f[0]]
    for k, terms in enumerate(fdb_terms(n), start=1):
        acc = jnp.zeros_like(coeffs[0])
        for coef, m, powers in terms:
            prod = f[m] * coef
            for j, e in powers:
                for _ in range(e):
                    prod = prod * coeffs[j]
            acc = acc + prod
        rows.append(acc)
    return jnp.stack(rows)


def jet_dense_ref(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  activation: str | None = "tanh") -> jnp.ndarray:
    """Fused layer oracle: (n+1, B, Din) @ (Din, Dout) + bias-on-c0, then
    the activation jet (or identity for the output layer)."""
    z = jnp.einsum("nbi,io->nbo", coeffs, w)
    z = z.at[0].add(b)
    if activation is None:
        return z
    return act_jet_ref(z, activation)
