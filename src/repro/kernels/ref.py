"""Pure-jnp oracles for the Pallas kernels.

These are *independent* straight-line implementations (no Pallas, no
core.jet reuse beyond the static tables) so kernel bugs cannot hide behind a
shared code path.  Tests sweep shapes/dtypes and assert allclose against
these.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.activations import sin_taylor_stack

from .bell_tables import fdb_terms, sigmoid_poly_rows, tanh_poly_rows

_POLY_ROWS = {"tanh": tanh_poly_rows, "sigmoid": sigmoid_poly_rows}
_PRIMAL = {"tanh": jnp.tanh, "sigmoid": lambda a: 0.5 * (jnp.tanh(0.5 * a) + 1.0)}


def _taylor_stack(a: jnp.ndarray, n: int, activation: str) -> list[jnp.ndarray]:
    """[sigma^(m)(a)/m! for m in 0..n] via Horner on the closed-form polys
    (tanh/sigmoid) or core.activations' sin phase cycle (same closed form the
    in-kernel stack hardcodes; only the polynomial tables stay independent)."""
    if activation == "sin":
        return list(sin_taylor_stack(a, n))
    u = _PRIMAL[activation](a)
    rows = _POLY_ROWS[activation](n)
    out = []
    for m in range(n + 1):
        row = rows[m]
        acc = jnp.full_like(u, row[-1])
        for c in row[-2::-1]:
            acc = acc * u + c
        out.append(acc)
    return out


def act_jet_ref(coeffs: jnp.ndarray, activation: str = "tanh") -> jnp.ndarray:
    """Faa di Bruno activation jet.  coeffs: (n+1, ...) scaled Taylor coeffs of
    the pre-activation; returns the same-shaped stack for sigma(pre-act)."""
    n = coeffs.shape[0] - 1
    f = _taylor_stack(coeffs[0], n, activation)
    rows = [f[0]]
    for k, terms in enumerate(fdb_terms(n), start=1):
        acc = jnp.zeros_like(coeffs[0])
        for coef, m, powers in terms:
            prod = f[m] * coef
            for j, e in powers:
                for _ in range(e):
                    prod = prod * coeffs[j]
            acc = acc + prod
        rows.append(acc)
    return jnp.stack(rows)


def jet_dense_ref(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  activation: str | None = "tanh") -> jnp.ndarray:
    """Fused layer oracle: (n+1, B, Din) @ (Din, Dout) + bias-on-c0, then
    the activation jet (or identity for the output layer)."""
    z = jnp.einsum("nbi,io->nbo", coeffs, w)
    z = z.at[0].add(b)
    if activation is None:
        return z
    return act_jet_ref(z, activation)


def jet_attention_scores_ref(q: jnp.ndarray, k: jnp.ndarray,
                             scale: float) -> jnp.ndarray:
    """Fused attention-score oracle: (n+1, B, T, D) Q/K coefficient stacks
    -> the softmaxed score jet (n+1, B, Tq, Tk).

    Straight-line: the Cauchy convolution of the score contraction, then the
    softmax exp / sum / div power-series recurrences written out directly
    (no core.jet, no shared kernel body)."""
    n1 = q.shape[0]
    s = [scale * sum(jnp.einsum("bqd,bkd->bqk", q[i], k[m - i])
                     for i in range(m + 1)) for m in range(n1)]
    shift = jnp.max(s[0], axis=-1, keepdims=True)
    e = [jnp.exp(s[0] - shift)]
    for m in range(1, n1):
        e.append(sum(j * s[j] * e[m - j] for j in range(1, m + 1)) / m)
    tot = [jnp.sum(em, axis=-1, keepdims=True) for em in e]
    p = [e[0] / tot[0]]
    for m in range(1, n1):
        p.append((e[m] - sum(tot[j] * p[m - j] for j in range(1, m + 1)))
                 / tot[0])
    return jnp.stack(p)


def jet_flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            wo: jnp.ndarray, scale: float,
                            mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Full fused-attention oracle: Q/K/V stacks (n+1, B, H, T, Dh) and the
    output projection ``wo`` (H, Dh, Dm) -> the attention-block output jet
    (n+1, B, T, Dm).

    Straight-line scores -> masked softmax -> value contraction -> output
    projection, all as explicit Cauchy convolutions / power-series
    recurrences (no core.jet, no shared kernel body, no online rescaling --
    the O(T^2)-memory computation the tiled kernel must reproduce).

    ``mask`` is a dense boolean (Tq, Tk) keep-matrix (True = attend); every
    query row must keep at least one key.  Masking replaces ``s_0`` with a
    large negative constant *before* the exp recurrence, so masked
    positions' whole e-jets vanish (exp underflows to exactly 0 and every
    higher coefficient carries an e-factor that is already 0) -- no
    inf/NaN enters even under differentiation.
    """
    n1 = q.shape[0]
    s = [scale * sum(jnp.einsum("bhqd,bhkd->bhqk", q[i], k[m - i])
                     for i in range(m + 1)) for m in range(n1)]
    if mask is not None:
        s[0] = jnp.where(mask, s[0], jnp.asarray(-1e30, s[0].dtype))
    shift = jnp.max(s[0], axis=-1, keepdims=True)
    e = [jnp.exp(s[0] - shift)]
    for m in range(1, n1):
        e.append(sum(j * s[j] * e[m - j] for j in range(1, m + 1)) / m)
    tot = [jnp.sum(em, axis=-1, keepdims=True) for em in e]
    p = [e[0] / tot[0]]
    for m in range(1, n1):
        p.append((e[m] - sum(tot[j] * p[m - j] for j in range(1, m + 1)))
                 / tot[0])
    o = [sum(jnp.einsum("bhqk,bhkd->bhqd", p[i], v[m - i])
             for i in range(m + 1)) for m in range(n1)]
    return jnp.stack([jnp.einsum("bhqd,hdo->bqo", om, wo) for om in o])


def jet_rms_norm_ref(coeffs: jnp.ndarray, gamma: jnp.ndarray,
                     eps: float = 1e-6) -> jnp.ndarray:
    """Fused rms_norm oracle: (n+1, B, W) stack + (W,) gain -> rms_norm jet.

    Straight-line mean-square convolution, binomial-series rsqrt (Miller
    recurrence, r = -1/2), normalizing convolution, gain."""
    n1 = coeffs.shape[0]
    ms = [sum(jnp.mean(coeffs[i] * coeffs[m - i], axis=-1, keepdims=True)
              for i in range(m + 1)) for m in range(n1)]
    ms[0] = ms[0] + eps
    inv = [1.0 / jnp.sqrt(ms[0])]
    for m in range(1, n1):
        inv.append(sum((0.5 * j - m) * ms[j] * inv[m - j]
                       for j in range(1, m + 1)) / (m * ms[0]))
    out = [sum(coeffs[m - j] * inv[j] for j in range(m + 1)) * gamma
           for m in range(n1)]
    return jnp.stack(out)
