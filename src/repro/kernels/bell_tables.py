"""Static coefficient tables shared by the Pallas kernels.

Everything here is plain Python / numpy computed at trace time and baked into
the kernel body as immediates: the Faa di Bruno partition terms (Taylor
normalization) and the tanh-derivative polynomial table.  Keeping them static
means the kernels contain no gather/table lookups -- just Horner chains and
fused multiply-adds, which is exactly what the TPU VPU wants.
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import List, Tuple

import numpy as np

from repro.core.activations import (sigmoid_derivative_polys,
                                    tanh_derivative_polys)
from repro.core.partitions import faa_di_bruno_table


@lru_cache(maxsize=None)
def tanh_poly_rows(n: int) -> Tuple[Tuple[float, ...], ...]:
    """Row m: coefficients (low->high, in u=tanh(a)) of tanh^(m) / m!."""
    polys = tanh_derivative_polys(n)
    rows = []
    for m, p in enumerate(polys):
        inv = 1.0 / math.factorial(m)
        rows.append(tuple(float(c) * inv for c in p))
    return tuple(rows)


@lru_cache(maxsize=None)
def sigmoid_poly_rows(n: int) -> Tuple[Tuple[float, ...], ...]:
    polys = sigmoid_derivative_polys(n)
    rows = []
    for m, p in enumerate(polys):
        inv = 1.0 / math.factorial(m)
        rows.append(tuple(float(c) * inv for c in p))
    return tuple(rows)


@lru_cache(maxsize=None)
def fdb_terms(n: int) -> Tuple[Tuple[Tuple[float, int, Tuple[Tuple[int, int], ...]], ...], ...]:
    """fdb_terms(n)[k-1] = tuple of (coef, m, powers) for output order k."""
    out = []
    for k in range(1, n + 1):
        out.append(tuple((float(t.coef), t.order, t.powers)
                         for t in faa_di_bruno_table(k)))
    return tuple(out)


def flop_estimate(n: int, batch: int, width: int) -> int:
    """Rough VPU FLOP count of one order-n tanh-jet epilogue on a tile."""
    per_elem = 0
    for k, terms in enumerate(fdb_terms(n), start=1):
        for _, _, powers in terms:
            per_elem += 2 + sum(e for _, e in powers)
    horner = sum(2 * (m + 1) for m in range(n + 1))
    return (per_elem + horner) * batch * width
