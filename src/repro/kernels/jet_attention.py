"""Pallas TPU kernels: fused jet attention scores + fused jet RMSNorm.

The transformer trunk's per-layer hot path (``repro.core.modules``) is

    S = (1/sqrt(d)) Q K^T        -- jet x jet Cauchy-convolved contraction
    P = softmax(S, axis=-1)      -- exp / sum / div power-series recurrences

and, around every block, ``rms_norm`` -- a Cauchy square, an rsqrt
recurrence, and a final Cauchy product.  Through the reference jet algebra
each of those steps is its own jnp op over the ``(n+1, ...)`` coefficient
stack, i.e. O(n^2) separate HBM round-trips per layer.  The two kernels here
fuse each chain into ONE launch:

``jet_attention_scores_pallas``
    loads a block of Q-jet and K-jet coefficient stacks into VMEM once, runs
    every Cauchy term of the score convolution as a batched ``dot_general``
    on the MXU, then the softmax exp/sum/div recurrences on the VPU with the
    whole coefficient axis in registers, and writes the probability jet once.

``jet_rms_norm_pallas``
    fuses the mean-square Cauchy convolution, the rsqrt jet (J.C.P. Miller
    recurrence for a^-1/2), the normalizing Cauchy product, and the gain in
    one VPU pass.

Tiling: the folded batch axis (collocation batch x heads for attention,
batch x tokens for rms_norm) is the only gridded dimension -- the token and
feature axes of a PINN transformer are tiny (T = d_in coordinates), so each
block holds them whole, and order k of any recurrence mixes all lower
orders, so the coefficient axis is never split.  Accumulation follows
jet_dense.py: MXU contractions run with ``preferred_element_type=float32``
and the output casts back to the input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Finite "minus infinity" for masked score positions: large enough that exp
# underflows to exactly 0, small enough that (NEG - NEG) stays 0.0 and no
# inf/NaN can enter the jet recurrences (a true -inf would produce inf-inf).
MASK_NEG = -1e30


def attention_scores_jet_body(q: jnp.ndarray, k: jnp.ndarray,
                              scale: float) -> jnp.ndarray:
    """The fused epilogue on in-VMEM stacks: (n+1, B, T, D) x 2 -> the
    softmaxed score jet (n+1, B, Tq, Tk).

    Shared by the Pallas kernel and (via the test sweeps) checked against
    the independent ``ref.jet_attention_scores_ref`` straight-line oracle.
    """
    n1 = q.shape[0]
    # accumulate in f32 for TPU-realistic dtypes (f32/bf16); float64 inputs
    # (the interpret-mode oracle tests) keep full precision
    acc_t = jnp.promote_types(q.dtype, jnp.float32)

    def qk(i: int, j: int) -> jnp.ndarray:
        # (B, T, D) x (B, T, D) -> (B, Tq, Tk), contracting D, batching B
        return jax.lax.dot_general(
            q[i], k[j],
            dimension_numbers=(((2,), (2,)), ((0,), (0,))),
            preferred_element_type=acc_t) * scale

    # Cauchy-convolved scores: s_k = scale * sum_{i+j=k} Q_i K_j^T
    s = []
    for m in range(n1):
        acc = qk(0, m)
        for i in range(1, m + 1):
            acc = acc + qk(i, m - i)
        s.append(acc)

    # softmax over the key axis via the exp/sum/div recurrences; the shift
    # is t-constant so it only enters e_0 and cancels in the division
    shift = jnp.max(s[0], axis=-1, keepdims=True)
    e = [jnp.exp(s[0] - shift)]
    for m in range(1, n1):
        acc = m * s[m] * e[0]
        for j in range(1, m):
            acc = acc + j * s[j] * e[m - j]
        e.append(acc / m)

    tot = [jnp.sum(em, axis=-1, keepdims=True) for em in e]
    inv0 = 1.0 / tot[0]
    p = [e[0] * inv0]
    for m in range(1, n1):
        acc = e[m]
        for j in range(1, m + 1):
            acc = acc - tot[j] * p[m - j]
        p.append(acc * inv0)
    return jnp.stack(p)


def _scores_kernel(q_ref, k_ref, o_ref, *, scale):
    out = attention_scores_jet_body(q_ref[...], k_ref[...], scale)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_b", "interpret"))
def jet_attention_scores_pallas(q: jnp.ndarray, k: jnp.ndarray, scale: float,
                                block_b: int = 64,
                                interpret: bool = True) -> jnp.ndarray:
    """(n+1, B, T, D) Q/K coefficient stacks -> softmaxed score jet
    (n+1, B, T, T), one launch.  B is the only gridded axis; padded batch
    rows are all-zero (uniform softmax) and sliced away on return."""
    n1, bsz, t, d = q.shape
    if k.shape != q.shape:
        raise ValueError(f"q/k shape mismatch: {q.shape} vs {k.shape}")
    bb = min(block_b, bsz)
    pb = (-bsz) % bb
    qp = jnp.pad(q, ((0, 0), (0, pb), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pb), (0, 0), (0, 0)))
    grid = (qp.shape[1] // bb,)
    out = pl.pallas_call(
        functools.partial(_scores_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1, bb, t, d), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((n1, bb, t, d), lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n1, bb, t, t), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n1, qp.shape[1], t, t), q.dtype),
        interpret=interpret,
    )(qp, kp)
    return out[:, :bsz]


# ---------------------------------------------------------------------------
# Flash-jet attention: the full block (scores + softmax + value contraction
# + output projection) in ONE launch, tiled over KV blocks with the online-
# softmax recurrence generalized to the jet coefficient axis.
#
# Per (batch, q-block) the kernel carries three running statistics in VMEM
# scratch across the innermost KV grid axis:
#
#   m  (bb, H, bq)        -- running max of the order-0 masked scores (the
#                            softmax shift; t-constant, so scalar per row)
#   t  (n+1, bb, H, bq)   -- running *total* jet: sum_k e_k of the shifted
#                            exp jet over every key seen so far
#   a  (n+1, bb, H, bq, D)-- running accumulator jet: the Cauchy product
#                            e (*) V summed over every key seen so far
#
# A shift change m -> m' rescales ALL coefficients of e by the same scalar
# alpha = exp(m - m'): the shift is t-constant, so exp(s - m') =
# exp(m - m') * exp(s - m) coefficient-wise.  Hence the flash update
#
#   t <- alpha * t + sum_block e,   a <- alpha * a + e (*) V_block.
#
# Because a = t (*) o (Cauchy), the epilogue recovers the attention output
# by JET DIVISION -- flash attention's "divide by the sum at the end"
# generalized to all orders:
#
#   o_0 = a_0 / t_0,   o_m = (a_m - sum_{j=1..m} t_j o_{m-j}) / t_0
#
# and immediately contracts o with the (H, Dh, Dm) output projection, so
# neither the (Tq, Tk) score jet nor the pre-projection per-head output
# ever materializes in HBM.
# ---------------------------------------------------------------------------


def _flash_block_keep(mask: str, window: int, i, j, block_q: int,
                      block_k: int, t_k: int) -> jnp.ndarray:
    """(bq, bk) boolean keep-matrix for q-block i / kv-block j in GLOBAL
    token coordinates: padded keys are always dropped, then the causal /
    local variant.  ``local(w)`` is a causal sliding window: query q attends
    keys j with q - w < j <= q (the diagonal is always kept, so no query
    row is ever fully masked)."""
    qi = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kj = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    keep = kj < t_k
    if mask == "causal":
        keep = keep & (kj <= qi)
    elif mask == "local":
        keep = keep & (kj <= qi) & (qi - kj < window)
    return keep


def _flash_kernel(q_ref, k_ref, v_ref, wo_ref, o_ref, m_ref, t_ref, a_ref, *,
                  scale, mask, window, t_k, block_q, block_k, n_kv):
    i, j = pl.program_id(1), pl.program_id(2)
    n1 = q_ref.shape[0]
    acc_t = m_ref.dtype
    neg = jnp.asarray(MASK_NEG, acc_t)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, neg, acc_t)
        t_ref[...] = jnp.zeros(t_ref.shape, acc_t)
        a_ref[...] = jnp.zeros(a_ref.shape, acc_t)

    q = q_ref[...].astype(acc_t)            # (n1, bb, H, bq, D)
    k = k_ref[...].astype(acc_t)            # (n1, bb, H, bk, D)
    v = v_ref[...].astype(acc_t)

    def qk(a_i: int, b_i: int) -> jnp.ndarray:
        # (bb, H, bq, D) x (bb, H, bk, D) -> (bb, H, bq, bk)
        return jax.lax.dot_general(
            q[a_i], k[b_i],
            dimension_numbers=(((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=acc_t) * scale

    # Cauchy-convolved scores for this tile: s_m = scale * sum Q_i K_j^T
    s = []
    for m in range(n1):
        acc = qk(0, m)
        for a_i in range(1, m + 1):
            acc = acc + qk(a_i, m - a_i)
        s.append(acc)

    keep = _flash_block_keep(mask, window, i, j, block_q, block_k, t_k)
    keep = keep[None, None]                 # broadcast over (bb, H)
    s0m = jnp.where(keep, s[0], neg)

    m_old = m_ref[...]                      # (bb, H, bq)
    m_new = jnp.maximum(m_old, jnp.max(s0m, axis=-1))
    alpha = jnp.exp(m_old - m_new)          # rescales every e coefficient

    # shifted exp jet for this tile; masked positions' e-jets are exactly 0:
    # e_0 underflows (exp(NEG - m_new)) and is where'd to 0, and every
    # higher e_m term carries an e-factor that is already 0
    e = [jnp.where(keep, jnp.exp(s0m - m_new[..., None]), 0.0)]
    for m in range(1, n1):
        acc = m * s[m] * e[0]
        for b_j in range(1, m):
            acc = acc + b_j * s[b_j] * e[m - b_j]
        e.append(acc / m)

    def ev(a_i: int, b_i: int) -> jnp.ndarray:
        # (bb, H, bq, bk) x (bb, H, bk, D) -> (bb, H, bq, D)
        return jax.lax.dot_general(
            e[a_i], v[b_i],
            dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=acc_t)

    esum, eav = [], []
    for m in range(n1):
        esum.append(jnp.sum(e[m], axis=-1))
        acc = ev(0, m)
        for a_i in range(1, m + 1):
            acc = acc + ev(a_i, m - a_i)
        eav.append(acc)

    t_new = alpha[None] * t_ref[...] + jnp.stack(esum)
    a_new = alpha[None, ..., None] * a_ref[...] + jnp.stack(eav)
    m_ref[...] = m_new
    t_ref[...] = t_new
    a_ref[...] = a_new

    @pl.when(j == n_kv - 1)
    def _epilogue():
        # a = t (*) o  =>  o by jet division, then the output projection.
        # t_0 >= 1 for every real query row (the row max contributes
        # exp(0)); the floor only catches padded query rows that a local
        # window can leave with zero kept keys, making them 0 not NaN.
        t0 = jnp.maximum(t_new[0], jnp.asarray(1e-37, acc_t))
        inv0 = 1.0 / t0[..., None]
        o = [a_new[0] * inv0]
        for m in range(1, n1):
            acc = a_new[m]
            for b_j in range(1, m + 1):
                acc = acc - t_new[b_j][..., None] * o[m - b_j]
            o.append(acc * inv0)
        wo = wo_ref[...].astype(acc_t)      # (H, D, Dm)
        out = [jax.lax.dot_general(
            om, wo, dimension_numbers=(((1, 3), (0, 1)), ((), ())),
            preferred_element_type=acc_t) for om in o]
        o_ref[...] = jnp.stack(out).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "mask", "window", "block_q", "block_k", "block_b", "interpret"))
def jet_flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                               wo: jnp.ndarray, scale: float,
                               mask: str = "none", window: int = 0,
                               block_q: int = 64, block_k: int = 64,
                               block_b: int = 8,
                               interpret: bool = True) -> jnp.ndarray:
    """Tiled flash-jet attention: Q/K/V coefficient stacks (n+1, B, H, T, Dh)
    plus the output projection (H, Dh, Dm) -> the attention-block output jet
    (n+1, B, T, Dm), one launch, no materialized (Tq, Tk) score jet.

    Grid is (batch, q-blocks, kv-blocks) with KV innermost; the running
    max / total-jet / accumulator-jet live in VMEM scratch and carry across
    the KV axis (TPU grids execute sequentially).  Peak memory is set by the
    block sizes, not T^2.  ``mask`` in {"none", "causal", "local"}; "local"
    attends the causal window ``q - window < key <= q``.  Padded batch rows
    are all-zero (uniform softmax over valid keys) and padded query rows may
    contain garbage; both slice away on return."""
    n1, bsz, h, t, d = q.shape
    if k.shape != q.shape or v.shape != q.shape:
        raise ValueError(f"q/k/v shape mismatch: {q.shape} vs {k.shape} "
                         f"vs {v.shape}")
    if wo.ndim != 3 or wo.shape[:2] != (h, d):
        raise ValueError(f"wo shape {wo.shape} incompatible with (H, Dh) = "
                         f"({h}, {d})")
    if mask not in ("none", "causal", "local"):
        raise ValueError(f"unknown mask variant {mask!r}")
    if mask == "local" and window < 1:
        raise ValueError(f"local mask needs window >= 1, got {window}")
    dm = wo.shape[2]
    bb = min(block_b, bsz)
    bq = min(block_q, t)
    bk = min(block_k, t)
    pb, pq, pk = (-bsz) % bb, (-t) % bq, (-t) % bk
    qp = jnp.pad(q, ((0, 0), (0, pb), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pb), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pb), (0, 0), (0, pk), (0, 0)))
    n_kv = (t + pk) // bk
    grid = ((bsz + pb) // bb, (t + pq) // bq, n_kv)
    acc_t = jnp.promote_types(q.dtype, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, mask=mask,
                          window=window, t_k=t, block_q=bq, block_k=bk,
                          n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1, bb, h, bq, d), lambda b, i, j: (0, b, 0, i, 0)),
            pl.BlockSpec((n1, bb, h, bk, d), lambda b, i, j: (0, b, 0, j, 0)),
            pl.BlockSpec((n1, bb, h, bk, d), lambda b, i, j: (0, b, 0, j, 0)),
            pl.BlockSpec((h, d, dm), lambda b, i, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n1, bb, bq, dm), lambda b, i, j: (0, b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n1, bsz + pb, t + pq, dm), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bb, h, bq), acc_t),
            pltpu.VMEM((n1, bb, h, bq), acc_t),
            pltpu.VMEM((n1, bb, h, bq, d), acc_t),
        ],
        interpret=interpret,
    )(qp, kp, vp, wo)
    return out[:, :bsz, :t]


def rms_norm_jet_body(x: jnp.ndarray, gamma: jnp.ndarray,
                      eps: float) -> jnp.ndarray:
    """Fused rms_norm jet on an in-VMEM stack: (n+1, B, W) -> same shape.

    mean-square Cauchy convolution -> rsqrt via the J.C.P. Miller recurrence
    (r = -1/2) -> normalizing Cauchy product -> gain.  Pure VPU work."""
    n1 = x.shape[0]

    ms = []
    for m in range(n1):
        acc = jnp.mean(x[0] * x[m], axis=-1, keepdims=True)
        for i in range(1, m + 1):
            acc = acc + jnp.mean(x[i] * x[m - i], axis=-1, keepdims=True)
        ms.append(acc)
    ms[0] = ms[0] + eps

    # Miller recurrence for ms^(-1/2): the r = -1/2 coefficient (r+1)j - m
    # simplifies to (0.5 j - m), spelled identically in ref.jet_rms_norm_ref
    inv0 = 1.0 / ms[0]
    inv = [jax.lax.rsqrt(ms[0])]
    for m in range(1, n1):
        acc = (0.5 - m) * ms[1] * inv[m - 1]            # j = 1 term
        for j in range(2, m + 1):
            acc = acc + (0.5 * j - m) * ms[j] * inv[m - j]
        inv.append(acc * inv0 / m)

    out = []
    for m in range(n1):
        acc = x[m] * inv[0]
        for j in range(1, m + 1):
            acc = acc + x[m - j] * inv[j]
        out.append(acc * gamma)
    return jnp.stack(out)


def _rms_norm_kernel(x_ref, g_ref, o_ref, *, eps):
    out = rms_norm_jet_body(x_ref[...], g_ref[...][0], eps)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_b", "interpret"))
def jet_rms_norm_pallas(coeffs: jnp.ndarray, gamma: jnp.ndarray,
                        eps: float = 1e-6, block_b: int = 256,
                        interpret: bool = True) -> jnp.ndarray:
    """(n+1, B, W) coefficient stack + (W,) gain -> rms_norm jet, one launch.
    The feature axis W is the reduction axis so each block holds it whole."""
    n1, bsz, w = coeffs.shape
    if gamma.shape != (w,):
        raise ValueError(f"gamma shape {gamma.shape} != ({w},)")
    bb = min(block_b, bsz)
    pb = (-bsz) % bb
    xp = jnp.pad(coeffs, ((0, 0), (0, pb), (0, 0)))
    # padded rows are all-zero: ms_0 = eps > 0, so the rsqrt recurrence
    # stays finite and the padding slices away cleanly
    grid = (xp.shape[1] // bb,)
    out = pl.pallas_call(
        functools.partial(_rms_norm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1, bb, w), lambda i: (0, i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n1, bb, w), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, coeffs.dtype),
        interpret=interpret,
    )(xp, gamma.reshape(1, -1))
    return out[:, :bsz]
