"""Pallas TPU kernels: fused jet attention scores + fused jet RMSNorm.

The transformer trunk's per-layer hot path (``repro.core.modules``) is

    S = (1/sqrt(d)) Q K^T        -- jet x jet Cauchy-convolved contraction
    P = softmax(S, axis=-1)      -- exp / sum / div power-series recurrences

and, around every block, ``rms_norm`` -- a Cauchy square, an rsqrt
recurrence, and a final Cauchy product.  Through the reference jet algebra
each of those steps is its own jnp op over the ``(n+1, ...)`` coefficient
stack, i.e. O(n^2) separate HBM round-trips per layer.  The two kernels here
fuse each chain into ONE launch:

``jet_attention_scores_pallas``
    loads a block of Q-jet and K-jet coefficient stacks into VMEM once, runs
    every Cauchy term of the score convolution as a batched ``dot_general``
    on the MXU, then the softmax exp/sum/div recurrences on the VPU with the
    whole coefficient axis in registers, and writes the probability jet once.

``jet_rms_norm_pallas``
    fuses the mean-square Cauchy convolution, the rsqrt jet (J.C.P. Miller
    recurrence for a^-1/2), the normalizing Cauchy product, and the gain in
    one VPU pass.

Tiling: the folded batch axis (collocation batch x heads for attention,
batch x tokens for rms_norm) is the only gridded dimension -- the token and
feature axes of a PINN transformer are tiny (T = d_in coordinates), so each
block holds them whole, and order k of any recurrence mixes all lower
orders, so the coefficient axis is never split.  Accumulation follows
jet_dense.py: MXU contractions run with ``preferred_element_type=float32``
and the output casts back to the input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def attention_scores_jet_body(q: jnp.ndarray, k: jnp.ndarray,
                              scale: float) -> jnp.ndarray:
    """The fused epilogue on in-VMEM stacks: (n+1, B, T, D) x 2 -> the
    softmaxed score jet (n+1, B, Tq, Tk).

    Shared by the Pallas kernel and (via the test sweeps) checked against
    the independent ``ref.jet_attention_scores_ref`` straight-line oracle.
    """
    n1 = q.shape[0]
    # accumulate in f32 for TPU-realistic dtypes (f32/bf16); float64 inputs
    # (the interpret-mode oracle tests) keep full precision
    acc_t = jnp.promote_types(q.dtype, jnp.float32)

    def qk(i: int, j: int) -> jnp.ndarray:
        # (B, T, D) x (B, T, D) -> (B, Tq, Tk), contracting D, batching B
        return jax.lax.dot_general(
            q[i], k[j],
            dimension_numbers=(((2,), (2,)), ((0,), (0,))),
            preferred_element_type=acc_t) * scale

    # Cauchy-convolved scores: s_k = scale * sum_{i+j=k} Q_i K_j^T
    s = []
    for m in range(n1):
        acc = qk(0, m)
        for i in range(1, m + 1):
            acc = acc + qk(i, m - i)
        s.append(acc)

    # softmax over the key axis via the exp/sum/div recurrences; the shift
    # is t-constant so it only enters e_0 and cancels in the division
    shift = jnp.max(s[0], axis=-1, keepdims=True)
    e = [jnp.exp(s[0] - shift)]
    for m in range(1, n1):
        acc = m * s[m] * e[0]
        for j in range(1, m):
            acc = acc + j * s[j] * e[m - j]
        e.append(acc / m)

    tot = [jnp.sum(em, axis=-1, keepdims=True) for em in e]
    inv0 = 1.0 / tot[0]
    p = [e[0] * inv0]
    for m in range(1, n1):
        acc = e[m]
        for j in range(1, m + 1):
            acc = acc - tot[j] * p[m - j]
        p.append(acc * inv0)
    return jnp.stack(p)


def _scores_kernel(q_ref, k_ref, o_ref, *, scale):
    out = attention_scores_jet_body(q_ref[...], k_ref[...], scale)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_b", "interpret"))
def jet_attention_scores_pallas(q: jnp.ndarray, k: jnp.ndarray, scale: float,
                                block_b: int = 64,
                                interpret: bool = True) -> jnp.ndarray:
    """(n+1, B, T, D) Q/K coefficient stacks -> softmaxed score jet
    (n+1, B, T, T), one launch.  B is the only gridded axis; padded batch
    rows are all-zero (uniform softmax) and sliced away on return."""
    n1, bsz, t, d = q.shape
    if k.shape != q.shape:
        raise ValueError(f"q/k shape mismatch: {q.shape} vs {k.shape}")
    bb = min(block_b, bsz)
    pb = (-bsz) % bb
    qp = jnp.pad(q, ((0, 0), (0, pb), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pb), (0, 0), (0, 0)))
    grid = (qp.shape[1] // bb,)
    out = pl.pallas_call(
        functools.partial(_scores_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1, bb, t, d), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((n1, bb, t, d), lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n1, bb, t, t), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n1, qp.shape[1], t, t), q.dtype),
        interpret=interpret,
    )(qp, kp)
    return out[:, :bsz]


def rms_norm_jet_body(x: jnp.ndarray, gamma: jnp.ndarray,
                      eps: float) -> jnp.ndarray:
    """Fused rms_norm jet on an in-VMEM stack: (n+1, B, W) -> same shape.

    mean-square Cauchy convolution -> rsqrt via the J.C.P. Miller recurrence
    (r = -1/2) -> normalizing Cauchy product -> gain.  Pure VPU work."""
    n1 = x.shape[0]

    ms = []
    for m in range(n1):
        acc = jnp.mean(x[0] * x[m], axis=-1, keepdims=True)
        for i in range(1, m + 1):
            acc = acc + jnp.mean(x[i] * x[m - i], axis=-1, keepdims=True)
        ms.append(acc)
    ms[0] = ms[0] + eps

    # Miller recurrence for ms^(-1/2): the r = -1/2 coefficient (r+1)j - m
    # simplifies to (0.5 j - m), spelled identically in ref.jet_rms_norm_ref
    inv0 = 1.0 / ms[0]
    inv = [jax.lax.rsqrt(ms[0])]
    for m in range(1, n1):
        acc = (0.5 - m) * ms[1] * inv[m - 1]            # j = 1 term
        for j in range(2, m + 1):
            acc = acc + (0.5 * j - m) * ms[j] * inv[m - j]
        inv.append(acc * inv0 / m)

    out = []
    for m in range(n1):
        acc = x[m] * inv[0]
        for j in range(1, m + 1):
            acc = acc + x[m - j] * inv[j]
        out.append(acc * gamma)
    return jnp.stack(out)


def _rms_norm_kernel(x_ref, g_ref, o_ref, *, eps):
    out = rms_norm_jet_body(x_ref[...], g_ref[...][0], eps)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_b", "interpret"))
def jet_rms_norm_pallas(coeffs: jnp.ndarray, gamma: jnp.ndarray,
                        eps: float = 1e-6, block_b: int = 256,
                        interpret: bool = True) -> jnp.ndarray:
    """(n+1, B, W) coefficient stack + (W,) gain -> rms_norm jet, one launch.
    The feature axis W is the reduction axis so each block holds it whole."""
    n1, bsz, w = coeffs.shape
    if gamma.shape != (w,):
        raise ValueError(f"gamma shape {gamma.shape} != ({w},)")
    bb = min(block_b, bsz)
    pb = (-bsz) % bb
    xp = jnp.pad(coeffs, ((0, 0), (0, pb), (0, 0)))
    # padded rows are all-zero: ms_0 = eps > 0, so the rsqrt recurrence
    # stays finite and the padding slices away cleanly
    grid = (xp.shape[1] // bb,)
    out = pl.pallas_call(
        functools.partial(_rms_norm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1, bb, w), lambda i: (0, i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n1, bb, w), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, coeffs.dtype),
        interpret=interpret,
    )(xp, gamma.reshape(1, -1))
    return out[:, :bsz]
