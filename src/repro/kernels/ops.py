"""Public jit'd wrappers around the Pallas kernels.

On a real TPU these run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body op-by-op and is what
the allclose test sweeps exercise.  The wrappers also pick TPU-aligned block
shapes and fall back to the pure-jnp reference for tiny shapes where a kernel
launch would be pure overhead.

This is also the dispatch surface for the compositional module layer
(``repro.core.modules``):

* :func:`jet_dense` / :func:`act_jet` accept **arbitrary leading batch
  axes** -- ``(n+1, *batch, D)`` -- and fold them into the kernel's batch
  dimension, so a transformer block's token axis rides the same fused
  kernel as a flat collocation batch (reshape is free: it never copies and
  is transparent to autodiff);
* :func:`epilogues` is the typed capability registry: one mapping from
  fusable name to :class:`EpilogueKind`.  ``ACTIVATION`` entries are the
  closed-form Taylor tables the dense kernel can run in its Faa di Bruno
  epilogue; ``FUSED_OP`` entries ("rms_norm", "attention_scores",
  "flash_attention") name dedicated whole-chain kernels reached via their
  own dispatch functions and are NOT valid dense epilogues.  The
  pre-redesign boolean pair ``supports_epilogue`` /
  ``supports_activation_epilogue`` is gone (it survived one PR as
  deprecated shims after the registry landed).
"""

from __future__ import annotations

import enum
import functools
from types import MappingProxyType
from typing import Mapping

import jax
import jax.numpy as jnp

from . import ref
from .jet_attention import (jet_attention_scores_pallas,
                            jet_flash_attention_pallas, jet_rms_norm_pallas)
from .jet_dense import jet_dense_pallas
from .tanh_jet import KERNEL_ACTS as _KERNEL_ACTS
from .tanh_jet import act_jet_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class EpilogueKind(enum.Enum):
    """What a fusable-name entry in :func:`epilogues` is capable of.

    ``ACTIVATION``
        a closed-form Taylor table the *dense kernel* can evaluate in its
        Faa di Bruno epilogue (also valid standalone via ``act_jet``);
    ``FUSED_OP``
        a dedicated whole-chain kernel (rms_norm, the PR-5 materializing
        attention scores, the tiled flash attention block) reached through
        its own dispatch function -- never a dense epilogue.
    """

    ACTIVATION = "activation"
    FUSED_OP = "fused_op"


# The typed fused-op registry: every name a module may ask about before
# routing a jet through a Pallas fast path instead of the reference algebra.
_EPILOGUE_KINDS: dict = {
    **{a: EpilogueKind.ACTIVATION for a in _KERNEL_ACTS},
    "rms_norm": EpilogueKind.FUSED_OP,
    "attention_scores": EpilogueKind.FUSED_OP,
    "flash_attention": EpilogueKind.FUSED_OP,
}


def epilogues() -> Mapping[str, EpilogueKind]:
    """The capability registry: fusable name -> :class:`EpilogueKind`,
    read-only.  ``epilogues().get(name) is EpilogueKind.ACTIVATION`` is the
    question a Dense/Activation leaf asks (can the dense kernel's Faa di
    Bruno epilogue run this activation); ``name in epilogues()`` is the
    broad does-a-fused-path-exist query."""
    return MappingProxyType(_EPILOGUE_KINDS)


def _fold_batch(coeffs: jnp.ndarray, keep: int = 1) -> tuple[jnp.ndarray, tuple]:
    """(n+1, *batch, *trailing) -> ((n+1, prod(batch), *trailing), batch),
    preserving the last ``keep`` axes -- 1 for the 3-D dense/norm kernels,
    2 for the 4-D attention core (token + feature pair stays whole).  The
    inverse is a plain reshape of the kernel output."""
    batch = coeffs.shape[1:-keep]
    flat = 1
    for s in batch:
        flat *= s
    return coeffs.reshape(coeffs.shape[:1] + (flat,) + coeffs.shape[-keep:]), \
        batch


# ---------------------------------------------------------------------------
# custom VJPs: forward runs the fused Pallas kernel; backward *recomputes*
# through the pure-jnp reference.  This is deliberate, not a workaround:
#  - residuals are just the layer inputs -> activation memory stays O(n M),
#    the paper's linear-memory claim, instead of stashing the (n+1)-stack
#    of every intermediate partition product;
#  - the recompute is one extra fused-layer-equivalent of FLOPs, the same
#    trade remat makes for ordinary transformer layers on TPU.
# The custom_vjp cores are 3-D ((n+1, B, D)); the public wrappers fold any
# extra leading batch axes around them.
# ---------------------------------------------------------------------------

def _act_jet_impl(coeffs: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation not in _KERNEL_ACTS:
        return ref.act_jet_ref(coeffs, activation)
    return act_jet_pallas(coeffs, activation, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _act_jet3(coeffs: jnp.ndarray, activation: str = "tanh") -> jnp.ndarray:
    return _act_jet_impl(coeffs, activation)


def _act_jet_fwd(coeffs, activation):
    return _act_jet_impl(coeffs, activation), coeffs


def _act_jet_bwd(activation, coeffs, g):
    _, vjp = jax.vjp(lambda c: ref.act_jet_ref(c, activation), coeffs)
    return vjp(g)


_act_jet3.defvjp(_act_jet_fwd, _act_jet_bwd)


def act_jet(coeffs: jnp.ndarray, activation: str = "tanh") -> jnp.ndarray:
    """Activation jet (n+1, *batch, W) -> same shape."""
    flat, batch = _fold_batch(coeffs)
    out = _act_jet3(flat, activation)
    return out.reshape(out.shape[:1] + batch + out.shape[-1:])


def _jet_dense_impl(coeffs, w, b, activation):
    if activation is not None and activation not in _KERNEL_ACTS:
        return ref.jet_dense_ref(coeffs, w, b, activation)
    return jet_dense_pallas(coeffs, w, b, activation, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _jet_dense3(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                activation: str | None = "tanh") -> jnp.ndarray:
    return _jet_dense_impl(coeffs, w, b, activation)


def _jet_dense_fwd(coeffs, w, b, activation):
    return _jet_dense_impl(coeffs, w, b, activation), (coeffs, w, b)


def _jet_dense_bwd(activation, res, g):
    coeffs, w, b = res
    _, vjp = jax.vjp(lambda c, ww, bb: ref.jet_dense_ref(c, ww, bb, activation),
                     coeffs, w, b)
    return vjp(g)


_jet_dense3.defvjp(_jet_dense_fwd, _jet_dense_bwd)


def jet_dense(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              activation: str | None = "tanh") -> jnp.ndarray:
    """Fused dense layer + activation jet: (n+1, *batch, Din) -> (n+1,
    *batch, Dout).  Extra leading batch axes (e.g. a token axis) fold into
    the kernel's GEMM M-dimension and unfold on the way out."""
    flat, batch = _fold_batch(coeffs)
    out = _jet_dense3(flat, w, b, activation)
    return out.reshape(out.shape[:1] + batch + out.shape[-1:])


# ---------------------------------------------------------------------------
# fused attention scores: Cauchy-product QK^T + scale + softmax recurrence
# in one launch (kernels/jet_attention.py); backward recomputes through the
# straight-line reference like every op above
# ---------------------------------------------------------------------------

def _attention_scores_impl(q, k, scale):
    return jet_attention_scores_pallas(q, k, scale, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _attention_scores4(q: jnp.ndarray, k: jnp.ndarray,
                       scale: float) -> jnp.ndarray:
    return _attention_scores_impl(q, k, scale)


def _attention_scores_fwd(q, k, scale):
    return _attention_scores_impl(q, k, scale), (q, k)


def _attention_scores_bwd(scale, res, g):
    q, k = res
    _, vjp = jax.vjp(
        lambda qq, kk: ref.jet_attention_scores_ref(qq, kk, scale), q, k)
    return vjp(g)


_attention_scores4.defvjp(_attention_scores_fwd, _attention_scores_bwd)


def jet_attention_scores(q_coeffs: jnp.ndarray, k_coeffs: jnp.ndarray,
                         scale: float) -> jnp.ndarray:
    """Fused attention-score jet: Q/K stacks (n+1, *batch, T, D) -> the
    softmaxed probability jet (n+1, *batch, Tq, Tk).  Extra leading batch
    axes (collocation batch, head axis) fold into the kernel's gridded batch
    dimension and unfold on the way out."""
    qf, batch = _fold_batch(q_coeffs, keep=2)
    kf, _ = _fold_batch(k_coeffs, keep=2)
    out = _attention_scores4(qf, kf, scale)
    return out.reshape(out.shape[:1] + batch + out.shape[-2:])


# ---------------------------------------------------------------------------
# tiled flash-jet attention: the whole block (scores + masked softmax +
# value contraction + output projection) in ONE launch with an online-
# softmax recurrence over KV blocks generalized to the coefficient axis --
# the "flash_attention" registry entry.  Backward recomputes through the
# straight-line reference (materializing, but only under differentiation).
# ---------------------------------------------------------------------------

def _flash_attention_impl(q, k, v, wo, scale, mask):
    kind, window = mask
    return jet_flash_attention_pallas(q, k, v, wo, scale, mask=kind,
                                      window=window,
                                      interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _flash_attention5(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      wo: jnp.ndarray, scale: float,
                      mask: tuple) -> jnp.ndarray:
    return _flash_attention_impl(q, k, v, wo, scale, mask)


def _flash_attention_fwd(q, k, v, wo, scale, mask):
    return _flash_attention_impl(q, k, v, wo, scale, mask), (q, k, v, wo)


def _flash_attention_bwd(scale, mask, res, g):
    from repro.core.modules import attention_mask
    q, k, v, wo = res
    dense = attention_mask(mask, q.shape[-2])
    _, vjp = jax.vjp(
        lambda qq, kk, vv, ww: ref.jet_flash_attention_ref(
            qq, kk, vv, ww, scale, mask=dense), q, k, v, wo)
    return vjp(g)


_flash_attention5.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def jet_flash_attention(q_coeffs: jnp.ndarray, k_coeffs: jnp.ndarray,
                        v_coeffs: jnp.ndarray, wo: jnp.ndarray, scale: float,
                        mask=None) -> jnp.ndarray:
    """Tiled flash-jet attention block: Q/K/V stacks (n+1, *batch, H, T, Dh)
    plus the output projection ``wo`` -- (H*Dh, Dm) as stored by
    ``SelfAttention`` (head-major rows), or already (H, Dh, Dm) -- to the
    block output jet (n+1, *batch, T, Dm) in one launch, never
    materializing the (Tq, Tk) score jet.  ``mask`` is anything
    ``repro.core.modules.normalize_attention_mask`` accepts.  Extra leading
    batch axes fold into the kernel's gridded batch dimension and unfold on
    the way out."""
    from repro.core.modules import normalize_attention_mask
    mask = normalize_attention_mask(mask)
    h, d = q_coeffs.shape[-3], q_coeffs.shape[-1]
    if wo.ndim == 2:
        wo = wo.reshape(h, d, wo.shape[-1])
    qf, batch = _fold_batch(q_coeffs, keep=3)
    kf, _ = _fold_batch(k_coeffs, keep=3)
    vf, _ = _fold_batch(v_coeffs, keep=3)
    out = _flash_attention5(qf, kf, vf, wo, scale, mask)
    return out.reshape(out.shape[:1] + batch + out.shape[-2:])


# ---------------------------------------------------------------------------
# fused rms_norm: mean-square convolution + rsqrt recurrence + gain in one
# launch (the "rms_norm" epilogue-registry entry)
# ---------------------------------------------------------------------------

def _rms_norm_impl(coeffs, gamma, eps):
    return jet_rms_norm_pallas(coeffs, gamma, eps, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_norm3(coeffs: jnp.ndarray, gamma: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    return _rms_norm_impl(coeffs, gamma, eps)


def _rms_norm_fwd(coeffs, gamma, eps):
    return _rms_norm_impl(coeffs, gamma, eps), (coeffs, gamma)


def _rms_norm_bwd(eps, res, g):
    coeffs, gamma = res
    _, vjp = jax.vjp(lambda c, gg: ref.jet_rms_norm_ref(c, gg, eps),
                     coeffs, gamma)
    return vjp(g)


_rms_norm3.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def jet_rms_norm(coeffs: jnp.ndarray, gamma: jnp.ndarray,
                 eps: float = 1e-6) -> jnp.ndarray:
    """Fused rms_norm jet: (n+1, *batch, W) -> same shape, normalized over
    the trailing feature axis and scaled by the (W,) gain.  Leading batch
    axes (token axis included) fold into the kernel batch dimension."""
    flat, batch = _fold_batch(coeffs)
    out = _rms_norm3(flat, gamma, eps)
    return out.reshape(out.shape[:1] + batch + out.shape[-1:])
