"""Public jit'd wrappers around the Pallas kernels.

On a real TPU these run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body op-by-op and is what
the allclose test sweeps exercise.  The wrappers also pick TPU-aligned block
shapes and fall back to the pure-jnp reference for tiny shapes where a kernel
launch would be pure overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .jet_dense import jet_dense_pallas
from .tanh_jet import KERNEL_ACTS as _KERNEL_ACTS
from .tanh_jet import act_jet_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# custom VJPs: forward runs the fused Pallas kernel; backward *recomputes*
# through the pure-jnp reference.  This is deliberate, not a workaround:
#  - residuals are just the layer inputs -> activation memory stays O(n M),
#    the paper's linear-memory claim, instead of stashing the (n+1)-stack
#    of every intermediate partition product;
#  - the recompute is one extra fused-layer-equivalent of FLOPs, the same
#    trade remat makes for ordinary transformer layers on TPU.
# ---------------------------------------------------------------------------

def _act_jet_impl(coeffs: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation not in _KERNEL_ACTS:
        return ref.act_jet_ref(coeffs, activation)
    return act_jet_pallas(coeffs, activation, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def act_jet(coeffs: jnp.ndarray, activation: str = "tanh") -> jnp.ndarray:
    """Activation jet (n+1, B, W) -> (n+1, B, W)."""
    return _act_jet_impl(coeffs, activation)


def _act_jet_fwd(coeffs, activation):
    return _act_jet_impl(coeffs, activation), coeffs


def _act_jet_bwd(activation, coeffs, g):
    _, vjp = jax.vjp(lambda c: ref.act_jet_ref(c, activation), coeffs)
    return vjp(g)


act_jet.defvjp(_act_jet_fwd, _act_jet_bwd)


def _jet_dense_impl(coeffs, w, b, activation):
    if activation is not None and activation not in _KERNEL_ACTS:
        return ref.jet_dense_ref(coeffs, w, b, activation)
    return jet_dense_pallas(coeffs, w, b, activation, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def jet_dense(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              activation: str | None = "tanh") -> jnp.ndarray:
    """Fused dense layer + activation jet: (n+1, B, Din) -> (n+1, B, Dout)."""
    return _jet_dense_impl(coeffs, w, b, activation)


def _jet_dense_fwd(coeffs, w, b, activation):
    return _jet_dense_impl(coeffs, w, b, activation), (coeffs, w, b)


def _jet_dense_bwd(activation, res, g):
    coeffs, w, b = res
    _, vjp = jax.vjp(lambda c, ww, bb: ref.jet_dense_ref(c, ww, bb, activation),
                     coeffs, w, b)
    return vjp(g)


jet_dense.defvjp(_jet_dense_fwd, _jet_dense_bwd)
