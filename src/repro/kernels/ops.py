"""Public jit'd wrappers around the Pallas kernels.

On a real TPU these run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body op-by-op and is what
the allclose test sweeps exercise.  The wrappers also pick TPU-aligned block
shapes and fall back to the pure-jnp reference for tiny shapes where a kernel
launch would be pure overhead.

This is also the dispatch surface for the compositional module layer
(``repro.core.modules``):

* :func:`jet_dense` / :func:`act_jet` accept **arbitrary leading batch
  axes** -- ``(n+1, *batch, D)`` -- and fold them into the kernel's batch
  dimension, so a transformer block's token axis rides the same fused
  kernel as a flat collocation batch (reshape is free: it never copies and
  is transparent to autodiff);
* :func:`supports_epilogue` tells a module whether an activation can fuse
  into the dense kernel's Faa di Bruno epilogue (one VMEM round-trip) or
  must compose through the reference jet algebra after the linear part.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .jet_dense import jet_dense_pallas
from .tanh_jet import KERNEL_ACTS as _KERNEL_ACTS
from .tanh_jet import act_jet_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def supports_epilogue(activation: str) -> bool:
    """True when the fused dense kernel can run ``activation`` in its
    epilogue (closed-form Taylor table baked into the kernel body)."""
    return activation in _KERNEL_ACTS


def _fold_batch(coeffs: jnp.ndarray) -> tuple[jnp.ndarray, tuple]:
    """(n+1, *batch, D) -> ((n+1, prod(batch), D), batch) for the 3-D
    kernels; the inverse is a plain reshape of the kernel output."""
    batch = coeffs.shape[1:-1]
    n1, d = coeffs.shape[0], coeffs.shape[-1]
    flat = 1
    for s in batch:
        flat *= s
    return coeffs.reshape(n1, flat, d), batch


# ---------------------------------------------------------------------------
# custom VJPs: forward runs the fused Pallas kernel; backward *recomputes*
# through the pure-jnp reference.  This is deliberate, not a workaround:
#  - residuals are just the layer inputs -> activation memory stays O(n M),
#    the paper's linear-memory claim, instead of stashing the (n+1)-stack
#    of every intermediate partition product;
#  - the recompute is one extra fused-layer-equivalent of FLOPs, the same
#    trade remat makes for ordinary transformer layers on TPU.
# The custom_vjp cores are 3-D ((n+1, B, D)); the public wrappers fold any
# extra leading batch axes around them.
# ---------------------------------------------------------------------------

def _act_jet_impl(coeffs: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation not in _KERNEL_ACTS:
        return ref.act_jet_ref(coeffs, activation)
    return act_jet_pallas(coeffs, activation, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _act_jet3(coeffs: jnp.ndarray, activation: str = "tanh") -> jnp.ndarray:
    return _act_jet_impl(coeffs, activation)


def _act_jet_fwd(coeffs, activation):
    return _act_jet_impl(coeffs, activation), coeffs


def _act_jet_bwd(activation, coeffs, g):
    _, vjp = jax.vjp(lambda c: ref.act_jet_ref(c, activation), coeffs)
    return vjp(g)


_act_jet3.defvjp(_act_jet_fwd, _act_jet_bwd)


def act_jet(coeffs: jnp.ndarray, activation: str = "tanh") -> jnp.ndarray:
    """Activation jet (n+1, *batch, W) -> same shape."""
    flat, batch = _fold_batch(coeffs)
    out = _act_jet3(flat, activation)
    return out.reshape(out.shape[:1] + batch + out.shape[-1:])


def _jet_dense_impl(coeffs, w, b, activation):
    if activation is not None and activation not in _KERNEL_ACTS:
        return ref.jet_dense_ref(coeffs, w, b, activation)
    return jet_dense_pallas(coeffs, w, b, activation, interpret=not _on_tpu())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _jet_dense3(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                activation: str | None = "tanh") -> jnp.ndarray:
    return _jet_dense_impl(coeffs, w, b, activation)


def _jet_dense_fwd(coeffs, w, b, activation):
    return _jet_dense_impl(coeffs, w, b, activation), (coeffs, w, b)


def _jet_dense_bwd(activation, res, g):
    coeffs, w, b = res
    _, vjp = jax.vjp(lambda c, ww, bb: ref.jet_dense_ref(c, ww, bb, activation),
                     coeffs, w, b)
    return vjp(g)


_jet_dense3.defvjp(_jet_dense_fwd, _jet_dense_bwd)


def jet_dense(coeffs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              activation: str | None = "tanh") -> jnp.ndarray:
    """Fused dense layer + activation jet: (n+1, *batch, Din) -> (n+1,
    *batch, Dout).  Extra leading batch axes (e.g. a token axis) fold into
    the kernel's GEMM M-dimension and unfold on the way out."""
    flat, batch = _fold_batch(coeffs)
    out = _jet_dense3(flat, w, b, activation)
    return out.reshape(out.shape[:1] + batch + out.shape[-1:])
