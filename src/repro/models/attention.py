"""Attention: GQA/MQA/MHA, sliding-window, softcap, qk_norm, KV cache.

Training/prefill uses a flash-style *blocked* formulation in pure JAX: scan
over query chunks with an online-softmax inner scan over KV chunks, so peak
activation memory is O(S * chunk) instead of O(S^2) -- this is what makes the
prefill_32k dry-run cells fit.  Local (sliding-window) layers instead
``dynamic_slice`` the exact KV span (chunk + window), paying zero wasted
FLOPs; global layers sweep all KV chunks with a causal mask (the ~2x masked
waste on strictly-causal blocks is a recorded hillclimb item, EXPERIMENTS.md
section Perf).

Decode attends one new token against a ring-buffer cache of seq_len entries
written at ``pos % S`` -- O(1) update, no roll-copy, window masking by
absolute position distance.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

from .layers import Maker, Params, rms_norm, rope, softcap

NEG = -2.0e38  # safe -inf for fp32 masks


def attn_specs(cfg: ArchConfig):
    """Pick shardable dims for the 16-way model axis.

    Preference order: shard heads (Megatron -- softmax stays local); if the
    head count doesn't divide (gemma3: 8 q heads, llama4: 40, whisper: 20),
    shard head_dim (pays a contraction all-reduce); tiny kv projections that
    divide neither way are replicated.  cfg.attn_sharding == "replicate"
    forces fully replicated attention weights: ~1 GiB/device extra weight
    memory buys zero attention collectives (section Perf knob)."""
    from repro.configs.base import MODEL_AXIS as MA

    if cfg.attn_sharding == "replicate":
        return P(None, None, None), P(None, None, None), P(None, None, None)

    def pick(n_heads, hd):
        if n_heads % MA == 0:
            return P(None, "model", None), "heads"
        if hd % MA == 0:
            # hd-sharding pays score all-reduces; right when attention is a
            # large flop share (whisper MHA, llama4 40H).  Archs with small/
            # windowed attention set attn_sharding="replicate" instead
            # (gemma3: measured 2x better -- section Perf 4.1/4.4).
            return P(None, None, "model"), "hd"
        return P(None, None, None), "none"

    q_spec, q_kind = pick(cfg.n_heads, cfg.hd)
    kv_spec, kv_kind = pick(cfg.n_kv_heads, cfg.hd)
    if q_kind == "heads" and kv_kind != "heads":
        # replicating the (small) kv projection keeps scores/softmax local;
        # hd-sharded kv against heads-sharded q forces SPMD full remats
        kv_spec, kv_kind = P(None, None, None), "none"
    elif q_kind == "hd" and cfg.hd % MA == 0:
        kv_spec, kv_kind = P(None, None, "model"), "hd"  # align kv on hd
    if q_kind == "heads":
        o_spec = P("model", None, None)
    elif q_kind == "hd":
        o_spec = P(None, "model", None)
    else:
        o_spec = P(None, None, None)
    return q_spec, kv_spec, o_spec


def q_hd_sharded(cfg: ArchConfig) -> bool:
    """True when attention shards head_dim (heads don't divide the axis)."""
    q_spec, _, _ = attn_specs(cfg)
    return len(q_spec) == 3 and q_spec[2] == "model"


def init_attn(mk: Maker, cfg: ArchConfig, cross: bool = False) -> Params:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q_spec, kv_spec, o_spec = attn_specs(cfg)
    p = {
        "wq": mk.param((d, h, hd), q_spec),
        "wk": mk.param((d, kvh, hd), kv_spec),
        "wv": mk.param((d, kvh, hd), kv_spec),
        "wo": mk.param((h, hd, d), o_spec),
    }
    if cfg.qk_norm:
        p["q_norm"] = mk.zeros((hd,), P(None))
        p["k_norm"] = mk.zeros((hd,), P(None))
    return p


def _project_qkv(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                 kv_x: jnp.ndarray | None = None):
    """Returns q:(B,Sq,H,hd), k,v:(B,Skv,KVH,hd), with qk_norm and no rope yet."""
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _scores(q, k, cfg: ArchConfig):
    """(B, KVH, G, Sq, Skv) grouped scores (GQA: G = H // KVH)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, h // kvh, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * (hd ** -0.5)
    return softcap(s.astype(jnp.float32), cfg.attn_softcap)


def _apply_probs(probs, v):
    """(B,KVH,G,Sq,Skv) x (B,Skv,KVH,hd) -> (B,Sq,H,hd)."""
    b, kvh, g, sq, _ = probs.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, kvh * g, -1)


# ---------------------------------------------------------------------------
# full (unblocked) attention -- encoder / cross-attention / tiny sequences
# ---------------------------------------------------------------------------

def full_attention(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                   *, causal: bool, window: Optional[int] = None,
                   kv_x: jnp.ndarray | None = None,
                   positions: jnp.ndarray | None = None,
                   use_rope: bool = True):
    """Returns (out, (k, v)) -- k/v post-rope, ready to become a cache."""
    b, sq, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    skv = k.shape[1]
    if use_rope:
        pos_q = jnp.arange(sq) if positions is None else positions
        pos_k = jnp.arange(skv)
        q = rope(q, pos_q, cfg.rope_theta)
        k = rope(k, pos_k, cfg.rope_theta)
    s = _scores(q, k, cfg)
    if causal:
        iq = jnp.arange(sq)[:, None]
        ik = jnp.arange(skv)[None, :]
        mask = ik <= iq
        if window is not None:
            mask &= ik > iq - window
        s = jnp.where(mask, s, NEG)
    probs = jax.nn.softmax(s, axis=-1)
    out = _apply_probs(probs, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


# ---------------------------------------------------------------------------
# blocked causal attention (training / prefill)
# ---------------------------------------------------------------------------

def blocked_attention(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                      *, window: Optional[int],
                      q_chunk: int = 512, kv_chunk: int = 1024):
    """Causal self-attention, O(S*chunk) memory.  window=None -> global.
    Returns (out, (k, v)) like full_attention."""
    b, s, d = x.shape
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    if s % q_chunk or s % kv_chunk:
        return full_attention(p, cfg, x, causal=True, window=window)

    q, k, v = _project_qkv(p, cfg, x)
    pos = jnp.arange(s)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    kvh, hd = k.shape[2], k.shape[3]
    g = cfg.n_heads // kvh
    nq = s // q_chunk

    # q rides the scan as xs (static slicing): the transpose of scan-ys is a
    # well-sharded stack, whereas dynamic-slice-by-index transposes into a
    # replicated scatter accumulation (measured 2.3 TB of all-gather on
    # gemma3 -- section Perf)
    qb = jnp.moveaxis(q.reshape(b, nq, q_chunk, cfg.n_heads, hd), 1, 0)

    if window is not None and window + q_chunk < s:
        # local layers: slice the exact KV span; zero wasted FLOPs
        span = q_chunk + window
        span = min(span + (-span) % kv_chunk, s)

        def one_q(_, inp):
            qi, qc = inp
            qs = qi * q_chunk
            ks_start = jnp.clip(qs + q_chunk - span, 0, s - span)
            kc = jax.lax.dynamic_slice_in_dim(k, ks_start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, ks_start, span, axis=1)
            sc = _scores(qc, kc, cfg)  # (B,KVH,G,Cq,span)
            ipos = qs + jnp.arange(q_chunk)[:, None]
            jpos = ks_start + jnp.arange(span)[None, :]
            mask = (jpos <= ipos) & (jpos > ipos - window)
            sc = jnp.where(mask, sc, NEG)
            probs = jax.nn.softmax(sc, axis=-1)
            return None, _apply_probs(probs, vc)  # (B,Cq,H,hd)

        # flash-attention memory profile: never save probabilities for the
        # backward -- recompute them per chunk (policy=nothing_saveable)
        one_q = jax.checkpoint(one_q,
                               policy=jax.checkpoint_policies.nothing_saveable)
        _, outs = jax.lax.scan(one_q, None, (jnp.arange(nq), qb))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, cfg.n_heads, hd)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)

    # global layers: online-softmax sweep over all KV chunks
    nk = s // kv_chunk
    kb = k.reshape(b, nk, kv_chunk, kvh, hd)
    vb = v.reshape(b, nk, kv_chunk, kvh, hd)

    def one_q(_, inp):
        qi, qc = inp
        qs = qi * q_chunk
        ipos = qs + jnp.arange(q_chunk)[:, None]

        def inner(carry, kj):
            m, l, acc = carry
            kc, vc = kb[:, kj], vb[:, kj]
            sc = _scores(qc, kc, cfg)  # (B,KVH,G,Cq,Ck)
            jpos = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jpos <= ipos
            if window is not None:
                mask &= jpos > ipos - window
            sc = jnp.where(mask, sc, NEG)
            m_new = jnp.maximum(m, sc.max(-1))
            corr = jnp.exp(m - m_new)
            pr = jnp.exp(sc - m_new[..., None])
            l_new = l * corr + pr.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", pr.astype(vc.dtype), vc)
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((b, kvh, g, q_chunk), NEG, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), jnp.arange(nk))
        out = acc / l[..., None]  # (B,KVH,G,Cq,hd)
        return None, jnp.moveaxis(out.reshape(b, kvh * g, q_chunk, hd), 1, 2)

    # flash-attention memory profile (see local branch above)
    one_q = jax.checkpoint(one_q,
                           policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(one_q, None, (jnp.arange(nq), qb))  # (nq,B,Cq,H,hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, cfg.n_heads, hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


# ---------------------------------------------------------------------------
# decode with ring-buffer KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, KVH, hd)
    v: jnp.ndarray  # (B, S, KVH, hd)


def decode_attention(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                     cache: KVCache, pos: jnp.ndarray,
                     *, window: Optional[int],
                     cross: bool = False) -> tuple[jnp.ndarray, KVCache]:
    """One-token step.  x: (B, 1, D); pos: () int32 -- absolute position of the
    new token; the cache holds the previous seq_len tokens (ring buffer)."""
    b, _, _ = x.shape
    s_max = cache.k.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x)
    if not cross:
        q = rope(q, pos[None], cfg.rope_theta)
        k_new = rope(k_new, pos[None], cfg.rope_theta)
        slot = jnp.mod(pos, s_max)
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
        cache = KVCache(ck, cv)
    sc = _scores(q, cache.k, cfg)  # (B,KVH,G,1,S)
    # absolute position of ring slot j given write head at slot(pos): entries
    # j hold positions pos - ((slot - j) mod S)
    slot = jnp.mod(pos, s_max)
    j = jnp.arange(s_max)
    age = jnp.mod(slot - j, s_max)  # 0 for the newest token
    kpos = pos - age
    mask = kpos >= 0
    if window is not None and not cross:
        mask &= age < window
    if not cross:
        sc = jnp.where(mask[None, None, None, None, :], sc, NEG)
    probs = jax.nn.softmax(sc, axis=-1)
    out = _apply_probs(probs, cache.v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def init_kv_cache(cfg: ArchConfig, batch: int, seq: int, n_layers: int,
                  abstract: bool = False, dtype=jnp.bfloat16) -> KVCache:
    shape = (n_layers, batch, seq, cfg.n_kv_heads, cfg.hd)
    if abstract:
        return KVCache(jax.ShapeDtypeStruct(shape, dtype),
                       jax.ShapeDtypeStruct(shape, dtype))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
