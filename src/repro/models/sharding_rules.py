"""Logical-axis sharding, mesh-agnostic.

Model code annotates activations with *logical* axis names ("batch", "model",
"seq", None); the launcher activates a ``Rules`` binding that maps them to
physical mesh axes.  With no active rules (pure-CPU unit tests) every
annotation is a no-op, so the same model runs un-meshed and on the
single-pod (data, model) and multi-pod (pod, data, model) meshes unchanged.

Physical binding used by launch/:
  batch -> (pod, data) | (data,)     seq -> (data,) when SP is on
  model -> (model,)                  fsdp -> (data,) for >=27B params
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class Rules:
    batch: Tuple[str, ...] = ()
    model: Tuple[str, ...] = ()
    seq: Tuple[str, ...] = ()
    fsdp: Tuple[str, ...] = ()

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        axes = getattr(self, logical)
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else axes


_ACTIVE: Optional[Rules] = None


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, rules
    try:
        yield
    finally:
        _ACTIVE = prev


def active_rules() -> Optional[Rules]:
    return _ACTIVE


def make_rules(mesh: jax.sharding.Mesh | None, *, sp: bool = False,
               fsdp: bool = False, policy: str = "tp") -> Rules:
    """policy="tp": model axis does tensor parallelism (default).
    policy="dp": the model axis joins the batch axes -- pure data parallelism
    for models small enough to replicate (section Perf: qwen3-0.6b)."""
    if mesh is None:
        return Rules()
    names = mesh.axis_names
    if policy == "dp":
        return Rules(
            batch=tuple(a for a in ("pod", "data", "model") if a in names),
            model=(),
            seq=(),
            fsdp=("data",) if (fsdp and "data" in names) else (),
        )
    return Rules(
        batch=tuple(a for a in ("pod", "data") if a in names),
        model=tuple(a for a in ("model",) if a in names),
        seq=("data",) if (sp and "data" in names) else (),
        fsdp=("data",) if (fsdp and "data" in names) else (),
    )


def shard(x, *logical):
    """Constrain with logical axes ("batch"/"model"/"seq"/None per dim)."""
    if _ACTIVE is None:
        return x
    spec = P(*(_ACTIVE.resolve(a) for a in logical))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def bind_pspec(spec: P, rules: Rules) -> P:
    """Bind a *logical* parameter PartitionSpec ("model"/"fsdp" entries) to
    physical axes; drops axes the mesh doesn't have."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        phys = []
        for e in entries:
            r = rules.resolve(e) if e in ("model", "fsdp", "batch", "seq") else e
            if r is None:
                continue
            phys.extend(r if isinstance(r, tuple) else (r,))
        out.append(tuple(phys) if len(phys) > 1 else (phys[0] if phys else None))
    return P(*out)
