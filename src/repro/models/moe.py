"""Mixture-of-Experts with sort-based capacity dispatch (MegaBlocks-style).

No (tokens x experts x capacity) one-hot is ever materialized -- at 32k
sequences that tensor is astronomically large.  Instead:

  1. top-k routing per token (renormalized softmax over the selected k);
  2. argsort the (N*k) slot->expert assignments;
  3. rank-within-expert via cumulative counts; slots with rank >= capacity
     drop (overflow goes to a trash row, standard capacity-factor semantics);
  4. scatter tokens into an (E*C, D) buffer, one dense einsum per expert
     group (MXU), gather back with combine weights.

Expert placement (logical specs, bound in launch/):
  * E >= 16 (llama4: 128): expert-parallel -- E sharded over "model";
  * E <  16 (mixtral: 8):  tensor-parallel inside each expert -- d_ff
    sharded over "model" (E stays replicated).

The auxiliary load-balance loss is the standard Switch formulation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

from .layers import Maker, Params
from .sharding_rules import shard

EP_MIN_EXPERTS = 16  # model-axis size on both assigned meshes


def init_moe(mk: Maker, cfg: ArchConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    if e >= EP_MIN_EXPERTS:  # expert parallel
        wi_spec, wo_spec = P("model", None, None, None), P("model", None, None)
    else:                    # TP within experts
        wi_spec, wo_spec = P(None, None, None, "model"), P(None, "model", None)
    return {
        "router": mk.param((d, e), P(None, None), scale=d ** -0.5),
        "wi": mk.param((e, d, 2, f), wi_spec),
        "wo": mk.param((e, f, d), wo_spec),
    }


DISPATCH_GROUPS = 32  # = pod x data shards; local dispatch per group


def apply_moe(p: Params, cfg: ArchConfig, x: jnp.ndarray,
              training: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss).

    Dispatch is *group-local*: tokens are split into DISPATCH_GROUPS groups
    aligned with the batch shards, each group routes and packs its own
    (E, cap_g) buffer.  The buffer carries both a group dim (sharded like
    batch) and an expert dim (sharded over "model" for EP), so routing
    arithmetic never crosses shards; only the expert einsum's implicit
    all-to-all moves tokens (GSPMD inserts it on the E axis).

    Capacity-factor drops are *training-only* load shaping: with
    ``training=False`` (inference: full forward, prefill, decode) dispatch is
    dropless (cap = n_loc, the per-expert worst case, since top-k indices
    are distinct per token), so the logits of a sequence routed jointly are
    identical to the same tokens decoded one at a time -- a lone decode
    token never contends for capacity, so any inference-time drop would
    break prefill+decode == full-forward parity."""
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    n = b * s
    g = DISPATCH_GROUPS
    while n % g:
        g //= 2
    n_loc = n // g
    if training:
        cap = max(1, min(int(math.ceil(n_loc * k / e * cfg.moe.capacity_factor)),
                         n_loc))
    else:
        cap = n_loc  # dropless: an expert can receive at most n_loc tokens

    xf = x.reshape(g, n_loc, d)
    xf = shard(xf, "batch", None, None)
    gates = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32),
                       p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(gates, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)               # (G,N_loc,k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux loss (Switch): E * sum_e f_e * P_e (global averages)
    me = probs.mean((0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * ce)

    def dispatch_one(xg, te, tw):
        """xg: (N_loc, D); te/tw: (N_loc, k) -> local pack tables."""
        flat_e = te.reshape(-1)
        flat_w = tw.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(n_loc), k)
        order = jnp.argsort(flat_e)
        se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
        counts = jnp.bincount(flat_e, length=e)
        offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                   jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(n_loc * k) - offsets[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + rank, e * cap)  # e*cap = dropped
        buf = jnp.zeros((e * cap, xg.shape[-1]), xg.dtype) \
            .at[slot].set(xg[stok], mode="drop")
        return buf.reshape(e, cap, -1), slot, stok, (sw * keep)

    h_in, slot, stok, sw = jax.vmap(dispatch_one)(xf, top_e, top_w)
    h_in = shard(h_in, "batch", "model" if e >= EP_MIN_EXPERTS else None,
                 None, None)

    gu = jnp.einsum("gecd,edtf->gectf", h_in, p["wi"])
    act = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
    h_out = jnp.einsum("gecf,efd->gecd", act, p["wo"])
    h_out = shard(h_out, "batch", "model" if e >= EP_MIN_EXPERTS else None,
                  None, None)

    def combine_one(ho, slot, stok, sw):
        out_buf = ho.reshape(e * cap, d)
        gathered = out_buf.at[slot].get(mode="fill", fill_value=0)
        gathered = gathered * sw.astype(out_buf.dtype)[:, None]
        return jnp.zeros((n_loc, d), out_buf.dtype).at[stok].add(gathered)

    y = jax.vmap(combine_one)(h_out, slot, stok, sw)
    y = shard(y, "batch", None, None)
    return y.reshape(b, s, d).astype(x.dtype), aux
