"""Composable decoder/encoder stacks covering all ten assigned architectures.

Layer weights are *stacked over scan groups*: the layer pattern (gemma3's
5 local : 1 global, llama4's dense/MoE interleave, zamba2's 6-mamba +
shared-attention period) defines a group; ``lax.scan`` iterates groups so the
HLO contains a single group body regardless of depth (compile time matters:
this container has one CPU core, and the dry-run compiles 40 cells x 2
meshes).  Layers that don't fill a whole group are unrolled as "rest"
(gemma3: 5 groups of 6 + 4 remainder).

Entry points (functional; params are plain dict pytrees):
  init_model(cfg, key, abstract)        -> (params, logical pspecs)
  train_loss(params, cfg, batch)        -> scalar loss, metrics
  prefill(params, cfg, batch)           -> last-pos hidden/logits + DecodeState
  decode_step(params, cfg, token, st)   -> logits, new DecodeState
  decode_state_specs(cfg, batch, seq)   -> abstract DecodeState (dry-run)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

from . import attention as attn
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .layers import (Maker, Params, StackedMaker, apply_mlp_block, embed,
                     init_embed, init_mlp_block, logits, rms_norm, unzip)
from .sharding_rules import shard

VLM_EMBED_DIM = 1024  # CLIP-large patch width (anyres frontend stub)


@dataclasses.dataclass(frozen=True)
class Knobs:
    """Performance knobs (hillclimbed in EXPERIMENTS.md section Perf)."""

    q_chunk: int = 512
    kv_chunk: int = 1024
    gla_chunk: int = 64
    rwkv_chunk: int = 32
    gla_pair_bf16: bool = False
    aux_coef: float = 0.01


def _attn_cfg(cfg: ArchConfig) -> ArchConfig:
    """cfg for zamba2's shared full-attention block."""
    return dataclasses.replace(cfg, block_type="attn", moe=None, mlp="gelu_mlp")


def _pattern_at(cfg: ArchConfig, j: int) -> str:
    return cfg.attn_pattern[j % len(cfg.attn_pattern)]


def _is_moe(cfg: ArchConfig, j: int) -> bool:
    return cfg.moe is not None and (j % cfg.moe.period == cfg.moe.period - 1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(mk: Maker, cfg: ArchConfig, j: int, cross: bool = False) -> Params:
    if cfg.block_type == "mamba2":
        return {"ln": mk.zeros((cfg.d_model,), P(None)),
                "mamba": ssm_mod.init_mamba(mk, cfg)}
    if cfg.block_type == "rwkv6":
        return {"ln1": mk.zeros((cfg.d_model,), P(None)),
                "tm": rwkv_mod.init_rwkv_tm(mk, cfg),
                "ln2": mk.zeros((cfg.d_model,), P(None)),
                "cm": init_mlp_block(mk, cfg)}
    lp: Params = {"ln1": mk.zeros((cfg.d_model,), P(None)),
                  "attn": attn.init_attn(mk, cfg),
                  "ln2": mk.zeros((cfg.d_model,), P(None))}
    if cross:
        lp["lnx"] = mk.zeros((cfg.d_model,), P(None))
        lp["xattn"] = attn.init_attn(mk, cfg, cross=True)
    if _is_moe(cfg, j):
        lp["moe"] = moe_mod.init_moe(mk, cfg)
    else:
        lp["ffn"] = init_mlp_block(mk, cfg)
    return lp


def _init_stack(mk: Maker, cfg: ArchConfig, cross: bool = False,
                n_layers: int | None = None) -> Params:
    n_layers = n_layers if n_layers is not None else cfg.n_layers
    g = cfg.group
    n_groups, n_rest = n_layers // g, n_layers % g
    smk = StackedMaker(mk, n_groups)
    groups = {"layers": [_init_layer(smk, cfg, j, cross) for j in range(g)]} \
        if n_groups else {"layers": []}
    rest = [_init_layer(mk, cfg, n_groups * g + r, cross) for r in range(n_rest)]
    return {"groups": groups, "rest": rest}


def init_model(cfg: ArchConfig, key: jax.Array | None = None,
               abstract: bool = False) -> Tuple[Params, Params]:
    """Returns (params, logical pspecs) -- structurally aligned pytrees."""
    if key is None:
        if not abstract:
            raise ValueError("concrete init needs a PRNG key")
        key = jax.random.PRNGKey(0)
    mk = Maker(key, jnp.dtype(cfg.dtype), abstract)
    tree: Dict[str, Any] = {
        "embed": init_embed(mk, cfg),
        "final_norm": mk.zeros((cfg.d_model,), P(None)),
        "stack": _init_stack(mk, cfg, cross=cfg.encoder is not None),
    }
    if cfg.hybrid_shared_attn_every:
        tree["shared"] = _init_layer(mk, _attn_cfg(cfg), 0)
    if cfg.encoder is not None:
        tree["enc_stack"] = _init_stack(mk, cfg, n_layers=cfg.encoder.n_layers)
        tree["enc_norm"] = mk.zeros((cfg.d_model,), P(None))
    if cfg.vlm_image_tokens:
        tree["projector"] = {
            "w1": mk.param((VLM_EMBED_DIM, cfg.d_model), P(None, "model")),
            "w2": mk.param((cfg.d_model, cfg.d_model), P("model", None)),
        }
    return unzip(tree)


# ---------------------------------------------------------------------------
# sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _sublayer_seq(lp: Params, cfg: ArchConfig, x: jnp.ndarray, j: int,
                  knobs: Knobs, *, causal: bool = True,
                  enc_out: jnp.ndarray | None = None,
                  collect_kv: bool = False, training: bool = False):
    """One layer.  Returns (x, kv, xkv, aux); kv/xkv None unless collected."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.block_type == "mamba2":
        x = x + ssm_mod.apply_mamba(lp["mamba"], cfg, rms_norm(x, lp["ln"]),
                                    chunk=knobs.gla_chunk)
        return x, None, None, aux
    if cfg.block_type == "rwkv6":
        x = x + rwkv_mod.apply_rwkv_tm(lp["tm"], cfg, rms_norm(x, lp["ln1"]),
                                       chunk=knobs.rwkv_chunk,
                                       pair_bf16=knobs.gla_pair_bf16)
        x = x + apply_mlp_block(lp["cm"], cfg, rms_norm(x, lp["ln2"]))
        return x, None, None, aux

    # Megatron-SP: residuals are S-sharded between layers; gather the
    # sequence ONCE on attention entry (chunked attention dynamic-slices
    # along S, which would otherwise all-gather per chunk).  Skipped for
    # hd-sharded attention: replicating h there turns the score contraction
    # into per-chunk all-reduces (whisper/llama4 regressed 2-3x; section
    # Perf 4.4) -- GSPMD's propagated sharding is better for that family.
    h = rms_norm(x, lp["ln1"])
    if not attn.q_hd_sharded(cfg):
        h = shard(h, "batch", None, None)
    if causal:
        window = cfg.window if _pattern_at(cfg, j) == "local" else None
        a_out, akv = attn.blocked_attention(lp["attn"], cfg, h, window=window,
                                            q_chunk=knobs.q_chunk,
                                            kv_chunk=knobs.kv_chunk)
    else:
        a_out, akv = attn.full_attention(lp["attn"], cfg, h, causal=False)
    x = x + a_out
    xkv = None
    if "xattn" in lp and enc_out is not None:
        c_out, xkv = attn.full_attention(lp["xattn"], cfg, rms_norm(x, lp["lnx"]),
                                         causal=False, kv_x=enc_out,
                                         use_rope=False)
        x = x + c_out
    h = rms_norm(x, lp["ln2"])
    if "moe" in lp:
        # batch-align the dispatch input here: S-sharded residuals hitting
        # the grouped dispatch otherwise reshard via per-layer all-to-alls
        h = shard(h, "batch", None, None)
        f_out, aux = moe_mod.apply_moe(lp["moe"], cfg, h, training=training)
    else:
        f_out = apply_mlp_block(lp["ffn"], cfg, h)
    x = x + f_out
    return x, (akv if collect_kv else None), (xkv if collect_kv else None), aux


def _stack_seq(stack: Params, cfg: ArchConfig, x: jnp.ndarray, knobs: Knobs,
               *, causal: bool = True, enc_out: jnp.ndarray | None = None,
               shared: Params | None = None, collect_kv: bool = False,
               training: bool = False):
    """Scan over groups + unrolled rest.

    Returns (x, aux, collected) with collected = dict of stacked kv pytrees
    (group axis leading) or None."""
    g = cfg.group
    shared_cfg = _attn_cfg(cfg) if shared is not None else None

    def group_body(carry, gparams):
        x, aux = carry
        kvs, xkvs = [], []
        for j in range(g):
            x, kv, xkv, a = _sublayer_seq(gparams["layers"][j], cfg, x, j, knobs,
                                          causal=causal, enc_out=enc_out,
                                          collect_kv=collect_kv,
                                          training=training)
            aux = aux + a
            if collect_kv:
                kvs.append(kv)
                xkvs.append(xkv)
        skv = None
        if shared is not None:
            x, skv, _, a = _sublayer_seq(shared, shared_cfg, x, 0, knobs,
                                         causal=causal, collect_kv=collect_kv)
            aux = aux + a
        # Megatron-SP residuals: the group-boundary activation (what remat
        # saves) is sequence-sharded over the model axis; attention/matmul
        # all-gather it on entry, norms/pointwise stay local.
        x = shard(x, "batch", "model", None)
        ys = (tuple(kvs), tuple(xkvs), skv) if collect_kv else None
        return (x, aux), ys

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    aux = jnp.zeros((), jnp.float32)
    grouped = None
    if stack["groups"]["layers"]:
        (x, aux), grouped = jax.lax.scan(body, (x, aux), stack["groups"])

    rest_kvs, rest_xkvs = [], []
    n_groups = len(stack["rest"]) and (cfg.n_layers // g)
    for r, lp in enumerate(stack["rest"]):
        x, kv, xkv, a = _sublayer_seq(lp, cfg, x, (cfg.n_layers // g) * g + r,
                                      knobs, causal=causal, enc_out=enc_out,
                                      collect_kv=collect_kv, training=training)
        aux = aux + a
        if collect_kv:
            rest_kvs.append(kv)
            rest_xkvs.append(xkv)
    collected = None
    if collect_kv:
        collected = {"grouped": grouped, "rest": tuple(rest_kvs),
                     "rest_x": tuple(rest_xkvs)}
    return x, aux, collected


def _fuse_inputs(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
                 knobs: Knobs):
    """Frontend fusion; returns (x, enc_out, n_prefix)."""
    enc_out = None
    n_prefix = 0
    if cfg.encoder is not None:
        e = batch["frames"].astype(jnp.dtype(cfg.dtype))
        e, _, _ = _stack_seq(params["enc_stack"], cfg, e, knobs, causal=False)
        enc_out = rms_norm(e, params["enc_norm"])
    x = embed(params["embed"], batch["tokens"], cfg)
    if cfg.vlm_image_tokens:
        pj = params["projector"]
        img = jax.nn.gelu(batch["image_embeds"].astype(x.dtype) @ pj["w1"],
                          approximate=True) @ pj["w2"]
        x = jnp.concatenate([img, x], axis=1)
        n_prefix = cfg.vlm_image_tokens
    return x, enc_out, n_prefix


def forward_seq(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
                knobs: Knobs = Knobs(), collect_kv: bool = False,
                training: bool = False):
    """``training`` gates training-only load shaping (MoE capacity drops);
    inference callers (prefill, eval forwards) keep the default False so the
    sequence forward is token-order-equivalent to step-wise decode."""
    x, enc_out, n_prefix = _fuse_inputs(params, cfg, batch, knobs)
    x = shard(x, "batch", None, None)
    shared = params.get("shared") if cfg.hybrid_shared_attn_every else None
    x, aux, collected = _stack_seq(params["stack"], cfg, x, knobs, causal=True,
                                   enc_out=enc_out, shared=shared,
                                   collect_kv=collect_kv, training=training)
    x = rms_norm(x, params["final_norm"])
    return x, aux, n_prefix, collected


CE_CHUNK = 512


def _ce_of_chunk(params, cfg, xc, tc):
    """Sum of (lse - picked) over one sequence chunk; logits never outlive
    the chunk (fused-CE pattern; cuts the f32 (B,S,V) buffer ~S/chunk-fold)."""
    lg = logits(params["embed"], xc, cfg).astype(jnp.float32)
    lg = shard(lg, "batch", None, "model")
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    # one-hot contraction instead of take_along_axis: stays sharded over the
    # model-parallel vocab axis (gather would all-gather the logits)
    hot = jax.nn.one_hot(tc, lg.shape[-1], dtype=lg.dtype)
    picked = jnp.einsum("bsv,bsv->bs", lg, hot)
    return jnp.sum(lse - picked)


def train_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
               knobs: Knobs = Knobs()):
    x, aux, n_prefix, _ = forward_seq(params, cfg, batch, knobs, training=True)
    tokens = batch["tokens"]
    if n_prefix:
        x = x[:, n_prefix:]
    x = x[:, :-1]
    tgt = tokens[:, 1:]
    n_pos = x.shape[0] * x.shape[1]
    s = x.shape[1]
    if s % CE_CHUNK == 0 and s > CE_CHUNK:
        nc = s // CE_CHUNK
        xb = jnp.moveaxis(x.reshape(x.shape[0], nc, CE_CHUNK, -1), 1, 0)
        tb = jnp.moveaxis(tgt.reshape(tgt.shape[0], nc, CE_CHUNK), 1, 0)

        def chunk_body(tot, inp):
            xc, tc = inp
            return tot + _ce_of_chunk(params, cfg, xc, tc), None

        body = jax.checkpoint(chunk_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
        ce_sum, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xb, tb))
    else:
        ce_sum = _ce_of_chunk(params, cfg, x, tgt)
    ce = ce_sum / n_pos
    loss = ce + knobs.aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------

def decode_state_specs(cfg: ArchConfig, batch: int, seq: int,
                       abstract: bool = True) -> Dict[str, Any]:
    st: Dict[str, Any] = {"pos": jax.ShapeDtypeStruct((), jnp.int32) if abstract
                          else jnp.asarray(seq - 1, jnp.int32)}
    kv_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.dtype(cfg.dtype)
    if cfg.block_type == "attn":
        st["kv"] = attn.init_kv_cache(cfg, batch, seq, cfg.n_layers, abstract, kv_dtype)
    if cfg.block_type == "mamba2":
        st["mamba"] = ssm_mod.init_mamba_state(cfg, batch, cfg.n_layers, abstract)
    if cfg.block_type == "rwkv6":
        st["rwkv"] = rwkv_mod.init_rwkv_state(cfg, batch, cfg.n_layers, abstract)
    if cfg.hybrid_shared_attn_every:
        n_apps = cfg.n_layers // cfg.group
        st["shared_kv"] = attn.init_kv_cache(_attn_cfg(cfg), batch, seq, n_apps,
                                             abstract, kv_dtype)
    if cfg.encoder is not None:
        st["cross_kv"] = attn.init_kv_cache(cfg, batch, cfg.encoder.seq,
                                            cfg.n_layers, abstract, kv_dtype)
    return st


_STATE_KEYS = ("kv", "mamba", "rwkv", "cross_kv")  # per-layer-stacked entries


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _sublayer_decode(lp: Params, cfg: ArchConfig, x, j: int,
                     sl: Dict[str, tuple], pos, knobs: Knobs):
    """sl: per-layer state slices.  Returns (x, new slices)."""
    new = dict(sl)
    if cfg.block_type == "mamba2":
        out, ms = ssm_mod.mamba_decode_step(lp["mamba"], cfg,
                                            rms_norm(x, lp["ln"]),
                                            ssm_mod.MambaState(*sl["mamba"]))
        new["mamba"] = tuple(ms)
        return x + out, new
    if cfg.block_type == "rwkv6":
        h = rms_norm(x, lp["ln1"])
        out, wkv, _ = rwkv_mod.rwkv_tm_decode_step(lp["tm"], cfg, h,
                                                   sl["rwkv"][0], sl["rwkv"][1])
        x = x + out
        h2 = rms_norm(x, lp["ln2"])
        cm_out = apply_mlp_block(lp["cm"], cfg, h2, x_prev=sl["rwkv"][2])
        new["rwkv"] = (wkv, h, h2)
        return x + cm_out, new
    window = cfg.window if _pattern_at(cfg, j) == "local" else None
    out, cache = attn.decode_attention(lp["attn"], cfg, rms_norm(x, lp["ln1"]),
                                       attn.KVCache(*sl["kv"]), pos,
                                       window=window)
    new["kv"] = tuple(cache)
    x = x + out
    if "xattn" in lp and "cross_kv" in sl:
        cout, _ = attn.decode_attention(lp["xattn"], cfg, rms_norm(x, lp["lnx"]),
                                        attn.KVCache(*sl["cross_kv"]), pos,
                                        window=None, cross=True)
        x = x + cout
    h = rms_norm(x, lp["ln2"])
    f_out = (moe_mod.apply_moe(lp["moe"], cfg, h)[0] if "moe" in lp
             else apply_mlp_block(lp["ffn"], cfg, h))
    return x + f_out, new


def decode_step(params: Params, cfg: ArchConfig, token: jnp.ndarray,
                st: Dict[str, Any], knobs: Knobs = Knobs()):
    """token: (B, 1) int32.  Returns (logits (B, V), new state)."""
    pos = st["pos"]
    x = embed(params["embed"], token, cfg)
    x = shard(x, "batch", None, None)
    g = cfg.group
    n_groups, n_rest = cfg.n_layers // g, cfg.n_layers % g
    present = [k for k in _STATE_KEYS if k in st]

    def group_slices(st):
        out = {}
        for k in present:
            out[k] = tuple(l[: n_groups * g].reshape((n_groups, g) + l.shape[1:])
                           for l in st[k])
        return out

    gstate = group_slices(st)
    shared = params.get("shared") if cfg.hybrid_shared_attn_every else None
    has_shared = "shared_kv" in st

    def group_body(x, xs):
        gparams, sl, skv = xs
        new_per_layer = []
        for j in range(g):
            slj = {k: tuple(l[j] for l in sl[k]) for k in present}
            x, nsl = _sublayer_decode(gparams["layers"][j], cfg, x, j, slj,
                                      pos, knobs)
            new_per_layer.append(nsl)
        ys = {k: tuple(jnp.stack([n[k][i] for n in new_per_layer])
                       for i in range(len(sl[k]))) for k in present}
        new_skv = skv
        if shared is not None:
            acfg = _attn_cfg(cfg)
            out, cache = attn.decode_attention(shared["attn"], acfg,
                                               rms_norm(x, shared["ln1"]),
                                               attn.KVCache(*skv), pos,
                                               window=None)
            x = x + out
            x = x + apply_mlp_block(shared["ffn"], acfg,
                                    rms_norm(x, shared["ln2"]))
            new_skv = tuple(cache)
        return x, (ys, new_skv)

    skv_xs = (tuple(st["shared_kv"]) if has_shared
              else (jnp.zeros((n_groups, 1)), jnp.zeros((n_groups, 1))))
    x, (new_gstate, new_skv) = jax.lax.scan(
        group_body, x, (params["stack"]["groups"], gstate, skv_xs))

    # unrolled rest layers
    rest_new: List[Dict[str, tuple]] = []
    for r, lp in enumerate(params["stack"]["rest"]):
        li = n_groups * g + r
        slr = {k: tuple(l[li] for l in st[k]) for k in present}
        x, nsl = _sublayer_decode(lp, cfg, x, li, slr, pos, knobs)
        rest_new.append(nsl)

    new_st = dict(st)
    new_st["pos"] = pos + 1
    for k in present:
        merged = []
        for i in range(len(st[k])):
            flat = new_gstate[k][i].reshape((n_groups * g,) + new_gstate[k][i].shape[2:])
            if n_rest:
                tail = jnp.stack([rn[k][i] for rn in rest_new])
                flat = jnp.concatenate([flat, tail], axis=0)
            merged.append(flat.astype(st[k][i].dtype))
        new_st[k] = type(st[k])(*merged) if hasattr(st[k], "_fields") else tuple(merged)
    if has_shared:
        new_st["shared_kv"] = attn.KVCache(*(s.astype(c.dtype) for s, c in
                                             zip(new_skv, st["shared_kv"])))

    x = rms_norm(x, params["final_norm"])
    lg = logits(params["embed"], x, cfg)[:, 0]
    return lg, new_st


# ---------------------------------------------------------------------------
# prefill (attention-cache architectures)
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            knobs: Knobs = Knobs(), pad_to: int | None = None):
    """Full-sequence forward that also builds the decode caches.

    ``pad_to`` sets the ring-buffer capacity (must exceed the prompt length
    by the number of tokens to be generated, or the ring evicts the oldest
    entries -- which is the intended streaming behavior at capacity).

    Supported for attention-backbone archs (incl. whisper cross-attention);
    mamba/rwkv per-token states are *not* assembled here -- SSM-family
    serving warms up via step-wise decode, see examples/serve_lm.py.
    Returns (last-position logits, DecodeState).
    """
    x, aux, n_prefix, collected = forward_seq(params, cfg, batch, knobs,
                                              collect_kv=True)
    lg = logits(params["embed"], x[:, -1:], cfg)[:, 0]
    seq = x.shape[1]
    cap = pad_to or seq
    assert cap >= seq, (cap, seq)

    def pad_cache(c: attn.KVCache) -> attn.KVCache:
        if cap == seq:
            return c
        pad = ((0, 0), (0, 0), (0, cap - seq), (0, 0), (0, 0))
        return attn.KVCache(jnp.pad(c.k, pad), jnp.pad(c.v, pad))

    st: Dict[str, Any] = {"pos": jnp.asarray(seq, jnp.int32)}
    if cfg.block_type != "attn":
        return lg, st  # SSM-family: no kv cache to assemble

    def assemble(grouped_idx, rest_list):
        """grouped ys: tuple over j of (k,v) with leading group axis."""
        ks, vs = [], []
        if collected["grouped"] is not None:
            per_j = collected["grouped"][grouped_idx]
            for j_entry in per_j:
                if j_entry is None:
                    continue
                k, v = j_entry  # (n_groups, B, S, kvh, hd)
                ks.append(k)
                vs.append(v)
            if ks:
                # interleave j within groups: (n_groups, j, ...) -> (L, ...)
                k = jnp.stack(ks, axis=1).reshape((-1,) + ks[0].shape[1:])
                v = jnp.stack(vs, axis=1).reshape((-1,) + vs[0].shape[1:])
                ks, vs = [k], [v]
        for entry in rest_list:
            if entry is None:
                continue
            k, v = entry
            ks.append(k[None])
            vs.append(v[None])
        if not ks:
            return None
        return attn.KVCache(jnp.concatenate(ks, 0), jnp.concatenate(vs, 0))

    kv = assemble(0, collected["rest"])
    if kv is not None:
        st["kv"] = pad_cache(kv)
    if cfg.encoder is not None:
        xkv = assemble(1, collected["rest_x"])
        if xkv is not None:
            st["cross_kv"] = xkv  # fixed encoder length; never ring-written
    if cfg.hybrid_shared_attn_every and collected["grouped"] is not None:
        skv = collected["grouped"][2]
        if skv is not None:
            st["shared_kv"] = pad_cache(attn.KVCache(*skv))
    return lg, st
