"""Mamba2 (SSD) block -- scalar-per-head decay through the shared GLA engine.

Faithful structure: fused in_proj -> [z | xBC | dt]; causal depthwise conv
(k=4) on xBC; per-head decay a_t = exp(-softplus(dt + bias) * exp(A_log));
y = C^T h with h the gated state; D skip; gated RMSNorm; out_proj.
n_groups = 1 (B/C shared across heads), headdim 64 -- the zamba2-2.7b layout.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

from .gla import chunked_gla, gla_decode_step
from .layers import Maker, Params, rms_norm

CONV_K = 4


class MambaState(NamedTuple):
    ssm: jnp.ndarray    # (B, H, N, hd)
    conv: jnp.ndarray   # (B, CONV_K-1, d_conv_channels)


def _dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    heads = cfg.ssm_heads or d_inner // 64
    hd = d_inner // heads
    n = cfg.ssm_state
    return d_inner, heads, hd, n


def init_mamba(mk: Maker, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_inner, heads, hd, n = _dims(cfg)
    d_conv = d_inner + 2 * n
    return {
        "in_proj": mk.param((d, 2 * d_inner + 2 * n + heads), P(None, "model")),
        "conv_w": mk.param((CONV_K, d_conv), P(None, "model"), scale=CONV_K ** -0.5),
        "conv_b": mk.zeros((d_conv,), P("model")),
        "a_log": mk.param((heads,), P("model"), scale=1.0),
        "dt_bias": mk.param((heads,), P("model"), scale=1.0),
        "d_skip": mk.param((heads,), P("model"), scale=1.0),
        "norm": mk.zeros((d_inner,), P("model")),
        "out_proj": mk.param((d_inner, d), P("model", None)),
    }


def _split(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    d_inner, heads, hd, n = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: 2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt


def _conv_train(p: Params, xbc: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv as a sum of shifted scalings (k=4)."""
    acc = p["conv_b"] + xbc * p["conv_w"][CONV_K - 1]
    for i in range(1, CONV_K):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        acc = acc + shifted * p["conv_w"][CONV_K - 1 - i]
    return jax.nn.silu(acc)


def apply_mamba(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                chunk: int = 64) -> jnp.ndarray:
    b, s, _ = x.shape
    d_inner, heads, hd, n = _dims(cfg)
    z, xbc, dt = _split(cfg, jnp.einsum("bsd,de->bse", x, p["in_proj"]))
    xbc = _conv_train(p, xbc)
    xin = xbc[..., :d_inner]
    bmat = xbc[..., d_inner: d_inner + n]
    cmat = xbc[..., d_inner + n:]

    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    log_decay = (-dt_act * jnp.exp(p["a_log"].astype(jnp.float32)))[..., None]  # (B,S,H,1)

    v = xin.reshape(b, s, heads, hd) * dt_act[..., None].astype(xin.dtype)
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, heads, n))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, heads, n))

    y, _ = chunked_gla(q, k, v, log_decay, mode="mamba", chunk=chunk)
    y = y + xin.reshape(b, s, heads, hd) * p["d_skip"].astype(y.dtype)[:, None]
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_mamba_state(cfg: ArchConfig, batch: int, n_layers: int,
                     abstract: bool = False, dtype=jnp.float32) -> MambaState:
    d_inner, heads, hd, n = _dims(cfg)
    shapes = ((n_layers, batch, heads, n, hd),
              (n_layers, batch, CONV_K - 1, d_inner + 2 * n))
    if abstract:
        return MambaState(*(jax.ShapeDtypeStruct(s, dtype) for s in shapes))
    return MambaState(*(jnp.zeros(s, dtype) for s in shapes))


def mamba_decode_step(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                      state: MambaState) -> tuple[jnp.ndarray, MambaState]:
    """x: (B, 1, D)."""
    b = x.shape[0]
    d_inner, heads, hd, n = _dims(cfg)
    z, xbc, dt = _split(cfg, jnp.einsum("bsd,de->bse", x, p["in_proj"]))
    xbc = xbc[:, 0]  # (B, C_conv)
    # conv with carried last K-1 inputs
    hist = jnp.concatenate([state.conv, xbc[:, None]], axis=1)  # (B, K, C)
    out = p["conv_b"] + jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                                   p["conv_w"].astype(jnp.float32))
    xbc_c = jax.nn.silu(out).astype(x.dtype)
    new_conv = hist[:, 1:]

    xin = xbc_c[..., :d_inner]
    bmat = xbc_c[..., d_inner: d_inner + n]
    cmat = xbc_c[..., d_inner + n:]
    dt_act = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    log_decay = (-dt_act * jnp.exp(p["a_log"].astype(jnp.float32)))[..., None]  # (B,H,1)

    v = xin.reshape(b, heads, hd) * dt_act[..., None].astype(xin.dtype)
    k = jnp.broadcast_to(bmat[:, None, :], (b, heads, n))
    q = jnp.broadcast_to(cmat[:, None, :], (b, heads, n))
    y, new_ssm = gla_decode_step(q, k, v, log_decay, state.ssm.astype(jnp.float32),
                                 mode="mamba")
    y = y + xin.reshape(b, heads, hd) * p["d_skip"].astype(y.dtype)[:, None]
    y = y.reshape(b, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, MambaState(new_ssm.astype(state.ssm.dtype), new_conv.astype(state.conv.dtype))
