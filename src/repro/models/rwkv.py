"""RWKV-6 (Finch) time-mix block: data-dependent per-channel decay.

Signature features kept faithful: token-shift lerp mixes for r/k/v/g/w, the
low-rank ("lora") data-dependent decay  w_t = exp(-exp(w0 + tanh(x_w A) B)),
per-head u bonus on the current token, per-head group norm on the readout,
SiLU gate.  The recurrence runs through the shared chunked GLA engine in
vector-decay mode.  Channel-mix (the FFN half) lives in layers.py
(``rwkv_channel_mix``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

from .gla import chunked_gla, gla_decode_step
from .layers import Maker, Params, token_shift

LORA_R = 64


class RWKVState(NamedTuple):
    wkv: jnp.ndarray      # (B, H, hd, hd)
    shift_tm: jnp.ndarray  # (B, 1, D) last token seen by time-mix
    shift_cm: jnp.ndarray  # (B, 1, D) last token seen by channel-mix


def init_rwkv_tm(mk: Maker, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.hd
    assert h * hd == d, (h, hd, d)
    return {
        "mix_r": mk.param((d,), P(None), scale=0.5),
        "mix_k": mk.param((d,), P(None), scale=0.5),
        "mix_v": mk.param((d,), P(None), scale=0.5),
        "mix_g": mk.param((d,), P(None), scale=0.5),
        "mix_w": mk.param((d,), P(None), scale=0.5),
        "wr": mk.param((d, d), P(None, "model")),
        "wk": mk.param((d, d), P(None, "model")),
        "wv": mk.param((d, d), P(None, "model")),
        "wg": mk.param((d, d), P(None, "model")),
        "w0": mk.param((d,), P("model"), scale=1.0),
        "w_lora_a": mk.param((d, LORA_R), P(None, None)),
        "w_lora_b": mk.param((LORA_R, d), P(None, "model"), scale=0.01),
        "u": mk.param((h, hd), P("model", None), scale=0.5),
        "ln_x": mk.zeros((d,), P("model")),
        "wo": mk.param((d, d), P("model", None)),
    }


def _mixes(p: Params, x: jnp.ndarray, xs: jnp.ndarray):
    def lerp(name):
        m = p[f"mix_{name}"]
        return x + (xs - x) * m

    return lerp("r"), lerp("k"), lerp("v"), lerp("g"), lerp("w")


def _log_decay(p: Params, xw: jnp.ndarray) -> jnp.ndarray:
    """w_t = exp(-exp(...)): returns log w_t (strictly negative)."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)) \
        @ p["w_lora_b"].astype(jnp.float32)
    return -jnp.exp(p["w0"].astype(jnp.float32) + lora)


def _group_norm(y: jnp.ndarray, gamma: jnp.ndarray, h: int, hd: int) -> jnp.ndarray:
    """Per-head RMS norm on the (…, H, hd) readout."""
    shp = y.shape
    yh = y.reshape(shp[:-1] + (h, hd)).astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(yh * yh, -1, keepdims=True) + 1e-5)
    yn = (yh * inv).reshape(shp)
    return yn.astype(y.dtype) * (1.0 + gamma.astype(y.dtype))


def apply_rwkv_tm(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                  chunk: int = 32, pair_bf16: bool = False) -> jnp.ndarray:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xs = token_shift(x, None)
    xr, xk, xv, xg, xw = _mixes(p, x, xs)
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    ld = _log_decay(p, xw).reshape(b, s, h, hd)
    y, _ = chunked_gla(r, k, v, ld, u=p["u"], mode="rwkv", chunk=chunk,
                       pair_bf16=pair_bf16)
    y = _group_norm(y.reshape(b, s, d), p["ln_x"], h, hd)
    return (y * g) @ p["wo"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_rwkv_state(cfg: ArchConfig, batch: int, n_layers: int,
                    abstract: bool = False, dtype=jnp.float32) -> RWKVState:
    h, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    shapes = ((n_layers, batch, h, hd, hd),
              (n_layers, batch, 1, d),
              (n_layers, batch, 1, d))
    if abstract:
        return RWKVState(*(jax.ShapeDtypeStruct(s, dtype) for s in shapes))
    return RWKVState(*(jnp.zeros(s, dtype) for s in shapes))


def rwkv_tm_decode_step(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                        wkv: jnp.ndarray, shift: jnp.ndarray
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,1,D); wkv: (B,H,hd,hd); shift: (B,1,D) previous token features."""
    b, _, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xr, xk, xv, xg, xw = _mixes(p, x, shift.astype(x.dtype))
    r = (xr @ p["wr"]).reshape(b, h, hd)
    k = (xk @ p["wk"]).reshape(b, h, hd)
    v = (xv @ p["wv"]).reshape(b, h, hd)
    g = jax.nn.silu(xg @ p["wg"])[:, 0]
    ld = _log_decay(p, xw).reshape(b, h, hd)
    y, new_wkv = gla_decode_step(r, k, v, ld, wkv.astype(jnp.float32),
                                 u=p["u"], mode="rwkv")
    y = _group_norm(y.reshape(b, d), p["ln_x"], h, hd)
    out = ((y * g) @ p["wo"])[:, None]
    return out, new_wkv.astype(wkv.dtype), x
