"""Model zoo: composable transformer/MoE/SSM/RWKV/hybrid stacks + PINN MLP."""

from . import attention, gla, layers, moe, rwkv, ssm, transformer
from .transformer import (Knobs, decode_state_specs, decode_step, forward_seq,
                          init_model, prefill, train_loss)
