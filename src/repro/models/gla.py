"""Chunked gated linear attention: the shared recurrence engine for Mamba2
(SSD) and RWKV-6 (Finch).

Recurrence (per head; Dk = key/state dim, Dv = value dim):

    S_t = diag(d_t) S_{t-1} + k_t v_t^T          d_t in (0,1]
    y_t = q_t^T S_t            (mamba mode: current token included, no bonus)
    y_t = q_t^T (S_{t-1} + diag(u) k_t v_t^T)    (rwkv mode: u-bonus diagonal)

Chunked evaluation (chunk C): with L_t = sum_{s<=t} log d_s (in-chunk cumsum),

    inter:  y_t += (q_t * exp(L_t'))  @ S_prev
    intra:  A[t,s] = sum_d q[t,d] k[s,d] exp(L'_t[d] - L_s[d]),  s <= t(-1)
    state:  S_new = diag(exp(L_C)) S_prev + sum_s (k_s * exp(L_C - L_s)) v_s^T

where L' is L shifted by one step in rwkv mode (decay applies *before* the
readout).  All exponents are differences with s <= t, hence <= 0 -- stable in
fp32 regardless of how aggressive the decay is (no 1/P blow-up).

Two decay layouts share this code:
  * scalar per head (mamba2): A factorizes, intra-chunk runs on the MXU as a
    plain (C,C) matmul times a decay matrix;
  * vector per channel (rwkv6): the pairwise tensor (C,C,Dk) is materialized
    per chunk -- the honest cost of per-channel gating (hillclimb note:
    secondary chunking can push this back onto the MXU).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class GLAState(NamedTuple):
    s: jnp.ndarray  # (B, H, Dk, Dv)


def chunked_gla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                log_decay: jnp.ndarray, *, u: Optional[jnp.ndarray] = None,
                mode: str = "mamba", chunk: int = 64,
                state: Optional[jnp.ndarray] = None,
                pair_bf16: bool = False
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """q,k: (B,S,H,Dk); v: (B,S,H,Dv); log_decay: (B,S,H,Dk) or (B,S,H,1)
    (scalar decay broadcast).  u: (H,Dk) rwkv bonus.  Returns (y, final_state).
    """
    b, s, h, dk = k.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c
    scalar_decay = log_decay.shape[-1] == 1

    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    ld = log_decay.astype(f32)

    def reshape_c(x):
        return x.reshape(b, nc, c, h, x.shape[-1])

    qc, kc, vc, ldc = (reshape_c(x) for x in (qf, kf, vf, ld))

    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)

    rwkv = mode == "rwkv"

    def body(s_prev, inputs):
        qi, ki, vi, ldi = inputs  # (B, C, H, *)
        L = jnp.cumsum(ldi, axis=1)           # inclusive in-chunk log decay
        Lq = (L - ldi) if rwkv else L         # shift: decay before readout
        Ltot = L[:, -1:]                      # (B,1,H,Dk*)

        # ----- inter-chunk: contribution of the carried state
        q_eff = _bcast(qi * jnp.exp(Lq), dk)
        y_inter = jnp.einsum("bchk,bhkv->bchv", q_eff, s_prev)

        # ----- intra-chunk
        t_idx = jnp.arange(c)
        mask = (t_idx[:, None] > t_idx[None, :]) if rwkv else \
               (t_idx[:, None] >= t_idx[None, :])
        if scalar_decay:
            # A[t,s] = (q_t . k_s) * exp(Lq_t - L_s): MXU matmul x decay matrix
            dots = jnp.einsum("bchk,bshk->bhcs", qi, ki)
            dec = Lq[..., 0].transpose(0, 2, 1)[:, :, :, None] - \
                  L[..., 0].transpose(0, 2, 1)[:, :, None, :]  # (B,H,C,C)
            A = dots * jnp.exp(jnp.where(mask[None, None], dec, -jnp.inf))
            A = jnp.where(mask[None, None], A, 0.0)
            y_intra = jnp.einsum("bhcs,bshv->bchv", A, vi)
        else:
            # per-channel decay: pairwise (B,C,C,H,Dk) tensor (rwkv6 cost).
            # pair_bf16 halves the dominant HBM term: exp(diff) in (0,1] and
            # q/k magnitudes make bf16 safe here (section Perf iteration).
            diff = Lq[:, :, None] - L[:, None, :, :]        # t x s
            diff = jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)
            if pair_bf16:
                # materialize the pairwise tensors in bf16 (exp(diff) lives in
                # (0,1]); contraction accumulates in f32 on the MXU.  Output
                # index order bcsh matches the consumer (kills layout
                # transposes of the pairwise tensor).
                eb = jnp.exp(diff.astype(jnp.bfloat16))      # exp in bf16 too
                prod = eb * ki.astype(jnp.bfloat16)[:, None]  # (B,Ct,Cs,H,Dk)
                A = jnp.einsum("bchk,bcshk->bcsh", qi.astype(jnp.bfloat16),
                               prod, preferred_element_type=jnp.float32)
                y_intra = jnp.einsum("bcsh,bshv->bchv", A, vi)
            else:
                A = jnp.einsum("bchk,bshk,bcshk->bhcs", qi, ki, jnp.exp(diff))
                y_intra = jnp.einsum("bhcs,bshv->bchv", A, vi)

        y = y_inter + y_intra
        if rwkv and u is not None:
            # diagonal bonus: y_t += (r_t . (u * k_t)) v_t
            y = y + jnp.sum(qi * u.astype(f32) * ki, -1, keepdims=True) * vi

        # ----- state update
        k_eff = _bcast(ki * jnp.exp(Ltot - L), dk)
        decay_tot = _bcast(jnp.exp(Ltot[:, 0]), dk)          # (B,H,Dk)
        s_new = decay_tot[..., None] * s_prev + \
            jnp.einsum("bchk,bchv->bhkv", k_eff, vi)
        return s_new, y

    # never save the pairwise decay tensors for backward -- recompute per
    # chunk (flash-style memory profile for the linear-recurrence path)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1), ldc.swapaxes(0, 1))
    state, ys = jax.lax.scan(body, state, xs)  # ys: (nc, B, C, H, Dv)
    y = ys.swapaxes(0, 1).reshape(b, s, h, dv)
    return y.astype(q.dtype), state


def _bcast(x, dk):
    """Broadcast a scalar-decay (..., 1) tensor to (..., Dk) lazily."""
    return jnp.broadcast_to(x, x.shape[:-1] + (dk,)) if x.shape[-1] == 1 else x


def _bcast_k(x, dk):
    return _bcast(x, dk)


def gla_decode_step(q, k, v, log_decay, state, *, u=None, mode="mamba"):
    """Single-token recurrence.  q,k: (B,H,Dk); v: (B,H,Dv);
    log_decay: (B,H,Dk) or (B,H,1); state: (B,H,Dk,Dv)."""
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    d = jnp.exp(log_decay.astype(f32))
    d = _bcast(d, kf.shape[-1])
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    if mode == "rwkv":
        bonus = kv * (u.astype(f32)[None, :, :, None] if u is not None else 1.0)
        y = jnp.einsum("bhk,bhkv->bhv", qf, state + bonus)
        new_state = d[..., None] * state + kv
    else:
        new_state = d[..., None] * state + kv
        y = jnp.einsum("bhk,bhkv->bhv", qf, new_state)
    return y.astype(q.dtype), new_state
