"""Shared building blocks: norms, RoPE, MLPs, embeddings, softcaps.

Parameters are plain dicts of jnp arrays (no framework dependency).  Every
init function has a matching ``*_specs`` twin used by the dry-run, which
builds the identical pytree out of ShapeDtypeStructs without allocating.
To keep that invariant automatically, inits are written against an abstract
"creator" -- ``zeros``-like for real init, ShapeDtypeStruct for specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


class Boxed:
    """A parameter leaf paired with its logical PartitionSpec.

    Init functions build trees of Boxed leaves; ``unzip`` splits them into a
    value tree and an aligned spec tree (launch/sharding binds the specs to
    the mesh).  This keeps params and shardings structurally identical by
    construction."""

    __slots__ = ("value", "spec")

    def __init__(self, value, spec: P):
        self.value = value
        self.spec = spec


def unzip(tree):
    is_box = lambda x: isinstance(x, Boxed)
    values = jax.tree_util.tree_map(lambda b: b.value, tree, is_leaf=is_box)
    specs = jax.tree_util.tree_map(lambda b: b.spec, tree, is_leaf=is_box)
    return values, specs


class Maker:
    """Creates either real initialized arrays or ShapeDtypeStructs (dry-run).

    Logical axis vocabulary in specs: "model" (TP), "fsdp" (weight sharding),
    None (replicated); binding to physical mesh axes happens in launch/.
    """

    def __init__(self, key: jax.Array | None, dtype, abstract: bool):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, shape, spec: P, scale: float | None = None, dtype=None) -> Boxed:
        dtype = dtype or self.dtype
        if self.abstract:
            return Boxed(jax.ShapeDtypeStruct(shape, dtype), spec)
        if scale is None:  # fan-in normal init
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = fan_in ** -0.5
        leaf = (jax.random.normal(self._next_key(), shape, jnp.float32) * scale
                ).astype(dtype)
        return Boxed(leaf, spec)

    def zeros(self, shape, spec: P, dtype=None) -> Boxed:
        dtype = dtype or self.dtype
        return Boxed(jax.ShapeDtypeStruct(shape, dtype) if self.abstract
                     else jnp.zeros(shape, dtype), spec)


class StackedMaker(Maker):
    """Maker that prepends a layer-group axis to every parameter it creates.

    Used for ``lax.scan``-over-groups weight stacking: init functions written
    for a single layer produce (n_groups, ...) leaves with a None-extended
    PartitionSpec, so the same init code serves scanned and unrolled layers.
    """

    def __init__(self, base: Maker, lead: int):
        super().__init__(None, base.dtype, base.abstract)
        self._base = base
        self._lead = lead

    def _ext(self, shape, spec: P):
        return (self._lead,) + tuple(shape), P(*((None,) + tuple(spec)))

    def param(self, shape, spec: P, scale: float | None = None, dtype=None) -> Boxed:
        shape, spec = self._ext(shape, spec)
        return self._base.param(shape, spec, scale=scale, dtype=dtype)

    def zeros(self, shape, spec: P, dtype=None) -> Boxed:
        shape, spec = self._ext(shape, spec)
        return self._base.zeros(shape, spec, dtype=dtype)


# logical spec aliases (bound to physical axes in launch/sharding.py)
REPL = P()
COL = P(None, "model")            # (d_in, d_out/TP)  column-parallel
ROW = P("model", None)            # (d_in/TP, d_out)  row-parallel
VOCAB = P("model", None)          # embedding table rows over TP


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (x32 * inv).astype(dt) * (1.0 + gamma.astype(dt))


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([(x1 * cos - x2 * sin).astype(x.dtype),
                            (x2 * cos + x1 * sin).astype(x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# dense FFNs
# ---------------------------------------------------------------------------

def init_mlp_block(mk: Maker, cfg: ArchConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wi": mk.param((d, 2, f), P(None, None, "model")),  # fused gate+up
            "wo": mk.param((f, d), ROW),
        }
    if cfg.mlp == "gelu_mlp":
        return {"wi": mk.param((d, f), COL), "wo": mk.param((f, d), ROW)}
    if cfg.mlp == "rwkv_channel_mix":
        return {
            "mix_k": mk.param((d,), REPL, scale=0.1),
            "wk": mk.param((d, f), COL),
            "wv": mk.param((f, d), ROW),
            "wr": mk.param((d, d), REPL),
        }
    raise ValueError(cfg.mlp)


def apply_mlp_block(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                    x_prev: jnp.ndarray | None = None) -> jnp.ndarray:
    if cfg.mlp in ("swiglu", "geglu"):
        gu = jnp.einsum("bsd,dtf->bstf", x, p["wi"])
        gate, up = gu[..., 0, :], gu[..., 1, :]
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate, approximate=True)
        return jnp.einsum("bsf,fd->bsd", act * up, p["wo"])
    if cfg.mlp == "gelu_mlp":
        return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(x @ p["wi"], approximate=True), p["wo"])
    if cfg.mlp == "rwkv_channel_mix":
        # RWKV channel mix: token-shifted key, squared-relu, receptance gate
        xs = token_shift(x, x_prev)
        xk = x + (xs - x) * p["mix_k"]
        k = jnp.square(jax.nn.relu(xk @ p["wk"]))
        r = jax.nn.sigmoid(x @ p["wr"])
        return r * (k @ p["wv"])
    raise ValueError(cfg.mlp)


def token_shift(x: jnp.ndarray, x_prev: jnp.ndarray | None) -> jnp.ndarray:
    """RWKV token shift: previous token's features (0 / carried state at t=0).

    x: (B, S, D).  ``x_prev``: (B, 1, D) carry from the previous segment
    (decode) or None (training from sequence start)."""
    first = jnp.zeros_like(x[:, :1]) if x_prev is None else x_prev.astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def init_embed(mk: Maker, cfg: ArchConfig) -> Params:
    p = {"table": mk.param((cfg.vocab, cfg.d_model), VOCAB,
                           scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        p["lm_head"] = mk.param((cfg.d_model, cfg.vocab), COL)
    return p


def embed(p: Params, tokens: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    x = jnp.take(p["table"], tokens, axis=0)
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def logits(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, p["table"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
    return softcap(out, cfg.logit_softcap)
