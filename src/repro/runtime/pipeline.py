"""Pipeline parallelism: a GPipe microbatch schedule on a "stage" mesh axis.

For meshes deeper than the assigned 2x16x16 (or models whose layers exceed
what FSDP+TP can hold), layer groups become pipeline stages.  This module
provides the deterministic schedule as a composable primitive:

  * the model's layer groups are stacked on a leading ``stage`` axis and
    shard_map splits them across the mesh axis;
  * microbatches stream through ``n_stages + n_micro - 1`` ticks; each tick
    every stage applies its block and ``ppermute``s activations rightward
    (the classic GPipe bubble of (P-1)/(P-1+M) idle fraction);
  * outputs collect at the last stage and are returned replicated.

The schedule is forward-only here (inference / activation streaming); for
training one wraps it in jax.grad -- JAX differentiates through ppermute,
yielding the reverse schedule automatically (bubble doubles, as in GPipe).

tests/test_distributed_subproc.py validates it against a sequential apply on
a 4-stage host mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn: Callable, mesh: jax.sharding.Mesh, *, axis: str = "stage"):
    """Build a pipelined apply: (stage_params, microbatches) -> outputs.

    ``stage_fn(params_one_stage, x_mb) -> y_mb`` must be shape-preserving
    (residual-block style), as every stage runs the same program.
    ``stage_params`` leaves are stacked on a leading axis of size n_stages;
    ``microbatches`` is (n_micro, mb, ...).
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, xs):
        n_micro = xs.shape[0]
        ticks = n_micro + n_stages - 1

        def inner(params, xs):
            # params: this stage's slice (leading axis stripped to size 1);
            # xs arrives fully replicated (in_specs P())
            params = jax.tree_util.tree_map(lambda a: a[0], params)
            sid = jax.lax.axis_index(axis)
            right = [(i, (i + 1) % n_stages) for i in range(n_stages)]

            def tick(buf, t):
                # stage 0 ingests microbatch t (when in range); others take
                # the activation handed over by the previous stage
                mb_idx = jnp.clip(t, 0, n_micro - 1)
                fresh = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, False)
                inp = jnp.where(sid == 0, fresh, buf)
                out = stage_fn(params, inp)
                handed = jax.lax.ppermute(out, axis, right)
                return handed, out

            _, outs = jax.lax.scan(tick, jnp.zeros_like(xs[0]),
                                   jnp.arange(ticks))
            # microbatch m exits the last stage at tick m + n_stages - 1
            done = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, n_micro, 0)
            # only the last stage holds real outputs; psum replicates them
            mask = (sid == n_stages - 1).astype(done.dtype)
            return jax.lax.psum(done * mask, axis)

        specs_p = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
        return shard_map(inner, mesh=mesh,
                         in_specs=(specs_p, P()),
                         out_specs=P(), check_rep=False)(stage_params, xs)

    return pipelined


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe idle fraction: (P-1)/(P-1+M); the scheduling-efficiency term."""
    return (n_stages - 1) / (n_stages - 1 + n_micro)
