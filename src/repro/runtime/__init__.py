from .metrics import LatencyStats, percentile
from .pipeline import gpipe, pipeline_bubble_fraction
from .trainer import Trainer, TrainerConfig, TrainerReport
