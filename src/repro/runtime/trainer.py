"""Fault-tolerant training runtime.

Production behaviors implemented (and exercised by tests/test_runtime.py):
  * checkpoint/restart: periodic async checkpoints; on ANY step failure the
    loop restores the latest checkpoint and resumes (transient-node-failure
    model).  Repeated failures back off and eventually re-raise.
  * preemption handling: SIGTERM sets a flag; the loop checkpoints at the
    next step boundary and exits cleanly (maintenance-event model).
  * straggler watchdog: per-step wall time is tracked with an EMA; steps
    slower than ``straggler_factor`` x EMA fire a callback (in a real fleet
    this triggers hot-spare swap / re-shard; here it is logged and counted --
    the hook point is what matters at 1000+ nodes).
  * elastic restart: restore() maps a checkpoint onto whatever mesh the new
    job built (see ckpt/manager.py) -- scale-up/down across restarts.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.ckpt import CheckpointManager


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_retries: int = 3
    straggler_factor: float = 3.0
    ema_alpha: float = 0.1


@dataclass
class TrainerReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: int = 0
    preempted: bool = False
    losses: List[float] = field(default_factory=list)


class Trainer:
    """Drives a jitted ``step_fn(state, batch) -> (state, loss)``."""

    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 batch_fn: Callable[[int], Any],
                 straggler_cb: Optional[Callable[[int, float, float], None]] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.straggler_cb = straggler_cb
        self._preempt = False
        self._ema: Optional[float] = None

    def _install_signal_handler(self):
        try:
            signal.signal(signal.SIGTERM, lambda *_: setattr(self, "_preempt", True))
        except ValueError:
            pass  # not on main thread (tests)

    def request_preempt(self):
        self._preempt = True

    def run(self, state: Any, start_step: int = 0,
            fail_injector: Optional[Callable[[int], None]] = None
            ) -> tuple[Any, TrainerReport]:
        self._install_signal_handler()
        report = TrainerReport()
        step = start_step
        retries = 0

        # resume from latest checkpoint if present
        latest = self.ckpt.latest_step()
        if latest is not None and latest >= start_step:
            state = self.ckpt.restore(latest, state)
            step = latest
            report.restarts += 0  # restore-at-boot is not a failure

        while step < self.cfg.total_steps:
            if self._preempt:
                self.ckpt.wait()
                self.ckpt.save(step, state, blocking=True)
                report.preempted = True
                break
            t0 = time.perf_counter()
            try:
                if fail_injector is not None:
                    fail_injector(step)
                batch = self.batch_fn(step)
                state, loss = self.step_fn(state, batch)
                loss = float(loss)
            except Exception:
                # node failure model: restore & retry from last checkpoint
                retries += 1
                report.restarts += 1
                if retries > self.cfg.max_retries:
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    self.ckpt.wait()
                    state = self.ckpt.restore(latest, state)
                    step = latest
                time.sleep(0.01 * 2 ** retries)  # backoff
                continue
            retries = 0
            dt = time.perf_counter() - t0
            if self._ema is not None and dt > self.cfg.straggler_factor * self._ema:
                report.stragglers += 1
                if self.straggler_cb:
                    self.straggler_cb(step, dt, self._ema)
            self._ema = dt if self._ema is None else \
                (1 - self.cfg.ema_alpha) * self._ema + self.cfg.ema_alpha * dt
            report.losses.append(loss)
            step += 1
            report.steps_run += 1
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, state, blocking=False)
        self.ckpt.wait()
        return state, report
