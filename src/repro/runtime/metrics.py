"""Latency/counter metrics shared by the runtime and serving layers.

A :class:`LatencyStats` is a thread-safe sliding-window reservoir of float
samples (seconds) with percentile snapshots -- the serving layer records
queue waits and end-to-end latencies into these, and the benchmark harness
reuses :func:`percentile` for its p50/p99 rows so both report the same
quantile definition (linear interpolation, numpy's default).
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Sequence

import numpy as np


def percentile(samples: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile of ``samples``; 0.0 when empty."""
    if not samples:
        return 0.0
    return float(np.percentile(np.asarray(samples, dtype=np.float64), q))


class LatencyStats:
    """Sliding-window latency reservoir (thread-safe).

    ``record`` keeps the last ``window`` samples for percentiles while the
    count/total accumulate over the full lifetime, so long-running servers
    report recent tail latency but exact request counts.
    """

    def __init__(self, window: int = 4096):
        self._samples: deque = deque(maxlen=window)
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))
            self.count += 1
            self.total += float(seconds)

    def snapshot(self) -> Dict[str, float]:
        """{count, mean_us, p50_us, p99_us} over the window (us = 1e-6 s)."""
        with self._lock:
            samples = list(self._samples)
            count, total = self.count, self.total
        return {
            "count": count,
            "mean_us": (total / count * 1e6) if count else 0.0,
            "p50_us": percentile(samples, 50) * 1e6,
            "p99_us": percentile(samples, 99) * 1e6,
        }
