"""Serve a trained operator PINN: train -> checkpoint -> hot derivative API.

    PYTHONPATH=src python examples/serve_operator.py --op heat --steps 300
    PYTHONPATH=src python examples/serve_operator.py --op kdv --order 3
    PYTHONPATH=src python examples/serve_operator.py --clients 8 --points 40

The end-to-end inference path: ``train_operator`` fits the PDE, the
parameters go through ``ckpt.CheckpointManager`` (atomic step directory),
and a :class:`repro.serving.DerivativeServer` restores them and serves
``(x, order)`` / ``(x, axes)`` queries for EVERY registered engine spec --
concurrent clients coalesce into shape-bucketed launches, compiled
executables are cached per (engine, order, bucket), and each response
carries queue-wait/pad/cache metrics.  Served tables are checked against a
direct ``engine.grid`` call before the per-spec metrics print.
"""

import argparse
import tempfile
import threading

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.ckpt import CheckpointManager  # noqa: E402
from repro.core.engines import DerivativeEngine  # noqa: E402
from repro.data.collocation import sample_box  # noqa: E402
from repro.pinn import (OperatorRunConfig, get_operator,  # noqa: E402
                        operator_names, train_operator)
from repro.serving import DerivativeServer  # noqa: E402

# every registered engine spec; mirrors benchmarks/operators_bench.SPECS
SPECS = ("ntp", "ntp/pallas", "autodiff")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default="heat", choices=list(operator_names()))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--order", type=int, default=None,
                    help="served derivative order (default: the operator's)")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client threads per engine spec")
    ap.add_argument("--points", type=int, default=24,
                    help="query points per client request")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: a temp dir)")
    args = ap.parse_args()

    op = get_operator(args.op)
    order = args.order if args.order is not None else op.order
    print(f"training {op.name} (d_in={op.d_in}, d_out={op.d_out}) ...")
    cfg = OperatorRunConfig(op=args.op, width=args.width, depth=args.depth,
                            adam_steps=args.steps, log_every=max(args.steps // 4, 1))
    res = train_operator(cfg)
    net = res.net
    print(f"  trained: loss {res.loss_history[0]:.2e} -> "
          f"{res.loss_history[-1]:.2e}, L2 vs exact {res.l2_error:.2e}")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="serve_operator_")
    CheckpointManager(ckpt_dir).save(args.steps, res.params, blocking=True)
    print(f"  checkpointed to {ckpt_dir}")

    key = jax.random.PRNGKey(7)
    queries = [sample_box(k, op.domain, args.points, jnp.float64)
               for k in jax.random.split(key, args.clients)]

    for spec in SPECS:
        engine = DerivativeEngine.from_spec(spec)
        with DerivativeServer.from_checkpoint(
                ckpt_dir, net, engine=spec, dtype=jnp.float64,
                flush_window_s=0.005) as server:
            results = [None] * args.clients

            def client(i, srv=server):
                results[i] = srv.grid(queries[i], order, timeout=120.0)

            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(args.clients)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()

            # every served table must agree with a direct engine call
            worst = 0.0
            for x, table in zip(queries, results):
                direct = jax.jit(
                    lambda p, xx: engine.grid(net, p, xx, order)
                )(server.params, x)
                worst = max(worst, float(jnp.max(jnp.abs(table - direct))))
            mixed = None
            if op.d_in > 1:
                mixed = server.cross(queries[0], (0, 1), timeout=120.0)

            m = server.metrics()
            print(f"\nengine {spec}: served {m['requests']} requests in "
                  f"{m['batches']} launches "
                  f"(max |served - direct| = {worst:.1e}"
                  + (f"; u_xy head {np.asarray(mixed)[0]}" if mixed is not None
                     else "") + ")")
            print(f"  latency p50 {m['latency']['p50_us']:.0f}us "
                  f"p99 {m['latency']['p99_us']:.0f}us | queue wait p50 "
                  f"{m['queue_wait']['p50_us']:.0f}us | pad fraction "
                  f"{m['pad_fraction_mean']:.2f}")
            c = m["cache"]
            print(f"  executable cache: {c['hits']} hits, {c['misses']} "
                  f"misses, {c['evictions']} evictions, size {c['size']}")


if __name__ == "__main__":
    main()
