"""n-TangentProp for transformers: Sobolev-regularized LM training.

    PYTHONPATH=src python examples/sobolev_lm.py --order 3 --steps 20

TangentProp (the 1991 original) penalized first derivatives along invariance
directions; the quasilinear n-jet makes ORDER-n smoothness penalties on a
*transformer* affordable: one extra forward pass carrying an (n+1)-deep
Taylor stack through attention/softmax/GeGLU, instead of n nested autodiff
sweeps.  This trains a small dense LM with loss

    CE + 1e-4 * || d^n h / dt^n ||^2,   t -> embeddings + t v

and prints both terms; watch the smoothness term fall while CE trains.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ShapeCfg
from repro.data.tokens import synthetic_batch
from repro.launch.ntp_reg import ntp_smoothness
from repro.models import init_model, train_loss
from repro.optim import adam_init, adam_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--order", type=int, default=3)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--coef", type=float, default=1e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    shape = ShapeCfg("sobolev", args.seq, args.batch, "train")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            ce, metrics = train_loss(p, cfg, batch)
            smooth = ntp_smoothness(p, cfg, batch, args.order)
            return ce + args.coef * smooth, (ce, smooth)

        (loss, (ce, smooth)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(grads, opt, params, 1e-3, grad_clip=1.0)
        return params, opt, ce, smooth

    for i in range(args.steps):
        t0 = time.perf_counter()
        params, opt, ce, smooth = step(params, opt, synthetic_batch(cfg, shape, i))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  ce={float(ce):.4f}  "
                  f"||d^{args.order}h||^2={float(smooth):.4e}  "
                  f"({(time.perf_counter() - t0) * 1e3:.0f} ms)")


if __name__ == "__main__":
    main()
