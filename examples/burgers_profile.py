"""End-to-end driver: self-similar Burgers shock profiles with a PINN
(paper section IV-C + appendix A).

    PYTHONPATH=src python examples/burgers_profile.py --k 1 --adam 1500 --lbfgs 300
    PYTHONPATH=src python examples/burgers_profile.py --k 3 --engine ntp   # 7 derivatives!

Finds the k-th smooth profile (lambda = 1/2k) by the combined forward-inverse
procedure: constrain lambda to [1/(2k+1), 1/(2k-1)], penalize
|d^(2k+1) R / dX^(2k+1)| near the origin, train Adam -> L-BFGS.  ``--engine
autodiff`` runs the identical schedule with nested autodiff (the paper's
baseline) for a wall-clock comparison; k >= 3 is where autodiff becomes
untenable and n-TangentProp keeps going.
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.pinn import (PINNRunConfig, exact_profile, profile_lambda,  # noqa: E402
                        train)
from repro.core.ntp import mlp_apply  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=1, help="profile index (lam=1/2k)")
    ap.add_argument("--engine", choices=["ntp", "ntp/pallas", "autodiff"],
                    default="ntp", help="derivative-engine spec")
    ap.add_argument("--adam", type=int, default=1500)
    ap.add_argument("--lbfgs", type=int, default=300)
    ap.add_argument("--width", type=int, default=24)
    ap.add_argument("--depth", type=int, default=3)
    args = ap.parse_args()

    cfg = PINNRunConfig(k=args.k, engine=args.engine,
                        adam_steps=args.adam, lbfgs_steps=args.lbfgs,
                        width=args.width, depth=args.depth)
    print(f"profile k={args.k}: target lambda = {profile_lambda(args.k)} | "
          f"smoothness order = {cfg.k * 2 + 1} "
          f"(=> {cfg.k * 2 + 2} network derivatives) | engine={args.engine}")
    res = train(cfg)

    print(f"\nlambda learned = {res.lam:.6f}  (target {profile_lambda(args.k)})")
    print(f"adam {res.adam_time_s:.1f}s, lbfgs {res.lbfgs_time_s:.1f}s, "
          f"final loss {res.loss_history[-1]:.3e}")

    # accuracy vs the closed-form profile (C=1 normalization)
    xs = np.linspace(-cfg.domain, cfg.domain, 401)
    u_true = exact_profile(xs, args.k)
    u_net = np.asarray(mlp_apply(res.params, jax.numpy.asarray(xs)[:, None]))[:, 0]
    l2 = np.sqrt(np.mean((u_net - u_true) ** 2))
    print(f"L2 error vs exact profile: {l2:.3e}")
    print("lambda history:", [f"{l:.4f}" for l in res.lam_history[-8:]])


if __name__ == "__main__":
    main()
