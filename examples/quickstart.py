"""Quickstart: n-TangentProp in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Computes f, f', ..., f^(8) of a tanh MLP in ONE forward pass, checks them
against nested autodiff, and shows the cost difference.
"""

import time

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import baselines, init_mlp, ntp_derivatives  # noqa: E402

# the paper's standard PINN network: 3 hidden layers x 24 neurons, tanh
params = init_mlp(jax.random.PRNGKey(0), d_in=1, width=24, depth=3, d_out=1,
                  dtype=jnp.float64)
x = jnp.linspace(-1.0, 1.0, 256, dtype=jnp.float64)[:, None]

N = 8
t0 = time.perf_counter()
derivs = ntp_derivatives(params, x, N)      # (N+1, batch, 1): f, f', ..., f^(8)
derivs.block_until_ready()
t_ntp = time.perf_counter() - t0
print(f"n-TangentProp: all {N + 1} derivatives in one pass "
      f"({t_ntp * 1e3:.1f} ms untraced)")

# independent oracle: nested reverse-mode autodiff (the O(M^n) way)
ref = baselines.nested_autodiff(params, x[:8], 6)
err = jnp.max(jnp.abs(derivs[:7, :8] - ref))
print(f"max |ntp - nested autodiff| over orders 0..6: {err:.2e}")

# jets through a full attention block work too (beyond the paper):
from repro.core import jet as J  # noqa: E402

h = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 16), jnp.float64)
v = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 16), jnp.float64)
jet = J.softmax(J.seed(h, v, 4), axis=-1)
print("4th directional derivative of softmax:", jet.coeffs[4].shape)
