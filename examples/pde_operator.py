"""Train a PINN on any registered differential operator.

    PYTHONPATH=src python examples/pde_operator.py --op heat --steps 2000
    PYTHONPATH=src python examples/pde_operator.py --op kdv --engine autodiff
    PYTHONPATH=src python examples/pde_operator.py --op poisson2d --engine ntp/pallas
    PYTHONPATH=src python examples/pde_operator.py --op advection-diffusion \
        --network fourier --fourier-features 32
    PYTHONPATH=src python examples/pde_operator.py --op navier-stokes   # 4th-order psi_xxyy
    PYTHONPATH=src python examples/pde_operator.py --op gray-scott      # d_out=2 system
    PYTHONPATH=src python examples/pde_operator.py --op heat --devices 4 \
        --grad-compression int8                 # data-parallel over 4 devices

Each operator carries a manufactured/exact solution: it supplies the
boundary/initial data during training and the L2 accuracy oracle at the end.
``--engine`` is a derivative-engine spec ("ntp", "ntp/pallas", "autodiff") --
``autodiff`` runs the identical objective through nested autodiff (the
paper's baseline); watch the per-step wall clock diverge as the operator's
derivative order grows (KdV needs u_xxx).  ``--network`` picks any
registered architecture: dense (paper), mlp, residual, fourier.

``--devices N`` shards collocation batches over an N-device "data" mesh
(``repro.parallel.jet_shard``); on a CPU-only host it forces N host
platform devices via XLA_FLAGS, which is why the heavy imports happen
*after* argument parsing.  ``--grad-compression int8|topk:F`` routes the
gradient all-reduce through the error-feedback compressors (off by
default: plain psum is exact).
"""

import argparse
import os


def parse_mask(text: str):
    """CLI spelling -> SelfAttention mask: none | causal | local:W."""
    text = text.strip().lower()
    if text in ("", "none"):
        return None
    if text == "causal":
        return "causal"
    if text.startswith("local:"):
        return ("local", int(text.split(":", 1)[1]))
    raise SystemExit(f"bad --mask {text!r}: expected none | causal | local:W")


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default="heat")
    ap.add_argument("--engine", default="ntp",
                    help="engine spec: ntp | ntp/pallas | autodiff")
    ap.add_argument("--network", default="dense")
    ap.add_argument("--fourier-features", type=int, default=16,
                    help="embedding size for --network fourier")
    ap.add_argument("--heads", type=int, default=2,
                    help="attention heads for --network transformer "
                         "(--width must be divisible by it)")
    ap.add_argument("--mask", default="none",
                    help="attention mask for --network transformer: "
                         "none | causal | local:W (e.g. local:4)")
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--lbfgs", type=int, default=0)
    ap.add_argument("--width", type=int, default=32)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--activation", default="tanh")
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--devices", type=int, default=0,
                    help="shard collocation batches over this many devices "
                         "(0 = single-device; forces host-platform devices "
                         "on CPU)")
    ap.add_argument("--grad-compression", default=None,
                    help="gradient all-reduce compression with --devices: "
                         "int8 | topk:F (default: exact fp psum)")
    ap.add_argument("--points", type=int, default=1024,
                    help="collocation points per step (must divide "
                         "--devices)")
    return ap.parse_args()


def main():
    args = parse_args()
    if args.devices > 1:
        # must land before jax initializes its backend: on a CPU host this
        # is how N "devices" come to exist at all
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}").strip()

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core import network_names
    from repro.pinn import (OperatorRunConfig, get_operator, operator_names,
                            train_operator)

    if args.op not in operator_names():
        raise SystemExit(f"unknown --op {args.op!r}; known: "
                         f"{', '.join(operator_names())}")
    if args.network not in network_names():
        raise SystemExit(f"unknown --network {args.network!r}; known: "
                         f"{', '.join(network_names())}")

    op = get_operator(args.op)
    print(f"operator {op.name}: {op.description}")
    print(f"  d_in={op.d_in}, d_out={op.d_out}, "
          f"max pure-derivative order={op.order}, "
          f"mixed partials={op.mixed or 'none'}, domain={op.domain}")
    print(f"  engine={args.engine}, network={args.network}, "
          f"devices={args.devices or 1}"
          + (f", grad_compression={args.grad_compression}"
             if args.grad_compression else ""))

    net_kwargs = {}
    if args.network == "fourier":
        net_kwargs["n_features"] = args.fourier_features
    elif args.network == "transformer":
        net_kwargs["n_heads"] = args.heads
        net_kwargs["mask"] = parse_mask(args.mask)
    cfg = OperatorRunConfig(op=args.op, engine=args.engine,
                            network=args.network, net_kwargs=net_kwargs,
                            adam_steps=args.steps, lbfgs_steps=args.lbfgs,
                            width=args.width, depth=args.depth,
                            activation=args.activation, adam_lr=args.lr,
                            n_domain=args.points,
                            data_parallel=args.devices,
                            grad_compression=args.grad_compression)
    res = train_operator(cfg)

    print(f"\nloss {res.loss_history[0]:.3e} -> {res.loss_history[-1]:.3e} "
          f"over {args.steps} Adam steps"
          + (f" + {args.lbfgs} L-BFGS steps" if args.lbfgs else ""))
    print(f"adam {res.adam_time_s:.1f}s, lbfgs {res.lbfgs_time_s:.1f}s, "
          f"{res.n_params} params")
    print(f"L2 error vs exact solution: {res.l2_error:.3e}")


if __name__ == "__main__":
    main()
