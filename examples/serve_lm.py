"""Batched serving example: prefill + decode across architecture families.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b
    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b   # SSM state path

Attention archs prefill the whole prompt in one pass and decode against the
ring-buffer KV cache; SSM/hybrid archs warm their recurrent state stepwise.
This is the same decode_step the decode_32k / long_500k dry-run cells lower
to 256/512 chips.
"""

import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import sys
    sys.argv = ["serve", "--arch", args.arch, "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len), "--gen", str(args.gen)]
    serve.main()


if __name__ == "__main__":
    main()
